# Convenience targets. `make check` is the tier-1 gate CI and PRs run.

.PHONY: check bench artifacts

check:
	./scripts/check.sh

# Perf trajectory: emits BENCH_batching.json / BENCH_throughput.json
# (the latter includes request-codec ns/op for API-overhead tracking).
bench:
	cargo bench --bench bench_batching
	cargo bench --bench bench_throughput

# AOT-compile model artifacts (requires the full Python/JAX build
# environment; see python/compile/aot.py).
artifacts:
	python3 python/compile/aot.py
