# Convenience targets. `make check` is the tier-1 gate CI and PRs run.

.PHONY: check bench artifacts

check:
	./scripts/check.sh

# Perf trajectory: emits BENCH_batching.json / BENCH_throughput.json /
# BENCH_http.json (request-codec and JSON-ingress ns/op for
# API-overhead tracking).
bench:
	cargo bench --bench bench_batching
	cargo bench --bench bench_throughput
	cargo bench --bench bench_http

# AOT-compile model artifacts (requires the full Python/JAX build
# environment; see python/compile/aot.py).
artifacts:
	python3 python/compile/aot.py
