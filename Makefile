# Convenience targets. `make check` is the tier-1 gate CI and PRs run.

.PHONY: check bench artifacts

# Includes a one-short-iteration run of every bench (compile + run
# guard; TENSORSERVE_BENCH_SMOKE=1 clips durations) so benches cannot
# silently rot.
check:
	./scripts/check.sh --bench-smoke

# Perf trajectory: emits BENCH_batching.json (incl. the contended-pool
# sharding mode and merge ratios), BENCH_throughput.json,
# BENCH_tail_latency.json (churn tails + lane isolation) and
# BENCH_http.json (request-codec and JSON-ingress ns/op).
bench:
	cargo bench --bench bench_batching
	cargo bench --bench bench_throughput
	cargo bench --bench bench_tail_latency
	cargo bench --bench bench_http

# AOT-compile model artifacts (requires the full Python/JAX build
# environment; see python/compile/aot.py).
artifacts:
	python3 python/compile/aot.py
