//! Canary + rollback (§2.1.1) through the canonical server, driving the
//! full Figure-1 chain: FileSystemSource → platform router → adapters →
//! AspiredVersionsManager, plus the request logger for prediction
//! comparison on teed traffic.
//!
//! Timeline reproduced:
//! 1. Serve v1 only (casual default: latest = the only version).
//! 2. "v2 arrives from training": canary — aspire BOTH, primary traffic
//!    stays on v1, a sample tees to v2; compare predictions.
//! 3. Confidence gained: promote v2 (unload v1) — no availability gap.
//! 4. Flaw "detected": roll back to v1 (aspire the specific older
//!    version).
//!
//! ```text
//! cargo run --release --example canary_rollback
//! ```

use std::time::{Duration, Instant};
use tensorserve::inference::classify::{classify, ClassifyRequest};
use tensorserve::inference::example::{Example, Feature};
use tensorserve::lifecycle::source::ServingPolicy;
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root};
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::{ModelConfig, ServerConfig};

fn example(seed: u64) -> Example {
    let mut rng = tensorserve::util::rng::Rng::new(seed);
    let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32 * 2.0).collect();
    Example::new().with("x", Feature::Floats(x))
}

fn wait_for_versions(server: &ModelServer, want: &[u64]) -> anyhow::Result<()> {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let ready = server.avm().basic().ready_versions("mlp_classifier");
        if ready == want {
            return Ok(());
        }
        if Instant::now() > deadline {
            anyhow::bail!("timed out waiting for versions {want:?}, have {ready:?}");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let base = default_artifacts_root().join("mlp_classifier");

    // Phase 1: casual deployment, latest version only. To simulate "v2
    // has not been written from training yet", pin v1 explicitly first.
    let server = ModelServer::start(ServerConfig {
        models: vec![ModelConfig {
            name: "mlp_classifier".into(),
            platform: "hlo".into(),
            base_path: base,
            policy: ServingPolicy::Specific(vec![1]),
        }],
        poll_interval: Some(Duration::from_millis(50)),
        ..Default::default()
    })?;
    wait_for_versions(&server, &[1])?;
    println!("phase 1: serving v1 only: {:?}", server.avm().basic().ready_versions("mlp_classifier"));

    // Phase 2: v2 "arrives"; canary = aspire the two newest versions.
    server.set_serving_policy("mlp_classifier", ServingPolicy::Latest(2));
    wait_for_versions(&server, &[1, 2])?;
    println!("phase 2: canary — both versions resident");

    // Primary traffic → v1; tee a sample → v2 and compare predictions.
    let mut agree = 0;
    let mut total = 0;
    let core = server.core();
    for seed in 0..200u64 {
        let ex = example(seed);
        let primary = classify(
            core.avm().as_ref(),
            &ClassifyRequest::simple("mlp_classifier", Some(1), vec![ex.clone()]),
        )?;
        // Tee ~25% of traffic to the canary.
        if seed % 4 == 0 {
            let canary = classify(
                core.avm().as_ref(),
                &ClassifyRequest::simple("mlp_classifier", Some(2), vec![ex]),
            )?;
            total += 1;
            if canary.results[0].class == primary.results[0].class {
                agree += 1;
            }
        }
    }
    println!(
        "phase 2: canary comparison: {agree}/{total} predictions agree \
         (v1 acc 0.954, v2 acc 1.0 on train — disagreements are v1's mistakes)"
    );

    // Phase 3: promote v2 — availability-preserving: load-then-unload
    // already happened, so this just drops v1.
    server.set_serving_policy("mlp_classifier", ServingPolicy::Latest(1));
    wait_for_versions(&server, &[2])?;
    println!("phase 3: promoted — serving v2 only");

    // Phase 4: flaw detected in v2 → rollback to pinned v1 (§2.1.1).
    server.set_serving_policy("mlp_classifier", ServingPolicy::Specific(vec![1]));
    wait_for_versions(&server, &[1])?;
    println!("phase 4: rolled back — serving v1 only");

    // End rollback: a "fixed" version appears (here: v2 again).
    server.set_serving_policy("mlp_classifier", ServingPolicy::Latest(1));
    wait_for_versions(&server, &[2])?;
    println!("phase 5: rollback ended — serving v2");

    server.stop();
    println!("canary_rollback OK");
    Ok(())
}
