//! TFS² hosted serving (§3.1, Figure 2): the full control plane over an
//! in-process cluster of real serving jobs.
//!
//! * Controller: "add model" / "add model version" / canary / rollback,
//!   RAM-estimate bin-packing onto jobs, state in the transactional
//!   store (the Spanner stand-in).
//! * Synchronizer: pushes aspired versions to jobs over RPC, polls
//!   status, publishes the routing table.
//! * Router: forwards inference with hedged backup requests.
//! * Autoscaler: reacts to load by scaling job replicas.
//!
//! ```text
//! cargo run --release --example tfs2_hosted
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;
use tensorserve::inference::example::{Example, Feature};
use tensorserve::rpc::client::ClientPool;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root, ArtifactSpec};
use tensorserve::tfs2::autoscaler::{Autoscaler, AutoscalerConfig};
use tensorserve::tfs2::cluster::Cluster;
use tensorserve::tfs2::controller::Controller;
use tensorserve::tfs2::router::Router;
use tensorserve::tfs2::store::Store;
use tensorserve::tfs2::synchronizer::Synchronizer;

fn sync_until_ready(
    sync: &Synchronizer,
    controller: &Controller,
    router: &Router,
    want_models: usize,
) -> anyhow::Result<()> {
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    loop {
        let report = sync.sync_once(&controller.desired_state())?;
        let table = sync.routing_table();
        if report.ready >= want_models && table.len() >= want_models {
            router.update_table(table);
            return Ok(());
        }
        if std::time::Instant::now() > deadline {
            anyhow::bail!("cluster never became ready: {report:?}");
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let artifacts = default_artifacts_root();

    // --- Infrastructure: 3 serving jobs, store, control plane. -------
    let cluster = Cluster::start(3, 64 << 20, artifacts.clone())?;
    let store = Store::in_memory(1);
    let controller = Controller::new(Arc::clone(&store));
    let pool = Arc::new(ClientPool::new());
    let sync = Synchronizer::new(Arc::clone(&store), Arc::clone(&pool));
    let router = Router::new(Duration::from_millis(50));

    for (id, addr, capacity) in cluster.jobs() {
        controller.register_job(&id, &addr, capacity)?;
    }
    println!("cluster up: {:?}", cluster.jobs());

    // --- "add model" x2: Controller estimates RAM from the spec and
    //     bin-packs (best-fit) onto jobs. ------------------------------
    for model in ["mlp_classifier", "mlp_regressor"] {
        let spec = ArtifactSpec::load(&artifacts.join(model).join("2"))?;
        let job = controller.add_model(
            model,
            artifacts.join(model).to_str().unwrap(),
            spec.ram_estimate_bytes,
            1, // start on v1
        )?;
        println!("controller placed {model} (est {}B) on {job}", spec.ram_estimate_bytes);
    }

    // --- Synchronizer reconciles; Router learns the table. -----------
    sync_until_ready(&sync, &controller, &router, 2)?;
    println!("routing table: {:?}", router.models());

    // --- Serve through the Router (hedged requests on by default). ---
    let mut rng = tensorserve::util::rng::Rng::new(7);
    let examples: Vec<Example> = (0..4)
        .map(|_| {
            let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32 * 2.0).collect();
            Example::new().with("x", Feature::Floats(x))
        })
        .collect();
    let resp = router.route(&Request::classify("mlp_classifier", None, examples.clone()))?;
    match &resp {
        Response::Classify { model_version, classes, .. } => {
            println!("classify via router: v{model_version} classes={classes:?}");
            assert_eq!(*model_version, 1);
        }
        other => anyhow::bail!("unexpected {other:?}"),
    }

    // --- "add model version" with canary. ----------------------------
    controller.set_canary("mlp_classifier", true)?;
    controller.add_version("mlp_classifier", 2)?;
    println!(
        "canary: desired versions now {:?}",
        controller.desired_versions("mlp_classifier")?
    );
    sync_until_ready(&sync, &controller, &router, 2)?;
    // Promote after comparing (see canary_rollback example for the
    // prediction-level comparison).
    controller.promote_canary("mlp_classifier")?;
    sync_until_ready(&sync, &controller, &router, 2)?;
    let resp = router.route(&Request::classify("mlp_classifier", None, examples))?;
    if let Response::Classify { model_version, .. } = resp {
        println!("after promote: served by v{model_version}");
        assert_eq!(model_version, 2);
    }

    // --- Rollback via the Controller. --------------------------------
    controller.rollback("mlp_classifier", 1)?;
    sync_until_ready(&sync, &controller, &router, 2)?;
    println!(
        "rollback: desired {:?}",
        controller.desired_versions("mlp_classifier")?
    );

    // --- Autoscaler: load spike on the classifier's job. -------------
    let mut scaler = Autoscaler::new(AutoscalerConfig {
        target_load_per_replica: 100.0,
        ..Default::default()
    });
    let job = controller.placement("mlp_classifier").unwrap();
    scaler.track(&job, 1);
    let decisions = scaler.tick(&HashMap::from([(job.clone(), 350.0)]));
    for d in &decisions {
        println!("autoscaler: {} {} -> {} replicas", d.job, d.from, d.to);
        cluster.scale_to(&d.job, d.to)?;
    }
    // Push assignments to the new replicas and route across them.
    let desired = controller.desired_state();
    let assignment = desired.iter().find(|a| a.job == job).unwrap();
    cluster.sync_replicas(&pool, &job, &assignment.models)?;
    let replicas = cluster.replica_addrs(&job);
    println!("job {job} now has {} replicas", replicas.len());
    assert!(replicas.len() > 1);

    println!(
        "router stats: {} requests, hedge rate {:.3}",
        router.registry.counter("router.requests").get(),
        router.hedge_rate()
    );
    cluster.stop();
    println!("tfs2_hosted OK");
    Ok(())
}
