//! REST quickstart: the HTTP/JSON path end-to-end, no artifacts or
//! PJRT backend required (synthetic servable).
//!
//! Starts a `ModelServer` with both listeners, loads two synthetic
//! versions of a multi-head model, and drives the TF-Serving-style
//! REST surface: predict in row and column formats, labeled
//! addressing, classify/regress, model status, label delete, and the
//! /metrics exposition.
//!
//! ```text
//! cargo run --release --example rest_quickstart
//! ```
//!
//! The same surface works with curl against `tensorserve_server
//! --http_port 8501`; every request below prints its curl equivalent.

use std::time::Duration;
use tensorserve::base::servable::ServableId;
use tensorserve::http::client::HttpClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::ArtifactSpec;
use tensorserve::runtime::hlo_servable::synthetic_loader;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::ServerConfig;

fn show(method: &str, path: &str, body: Option<&str>, status: u16, reply: &[u8]) {
    match body {
        Some(b) => println!("\n$ curl -X {method} localhost:8501{path} -d '{b}'"),
        None => println!("\n$ curl -X {method} localhost:8501{path}"),
    }
    println!("  → {status} {}", String::from_utf8_lossy(reply));
}

fn main() -> anyhow::Result<()> {
    // 1. A server with the REST gateway enabled (ephemeral ports).
    let server = ModelServer::start(ServerConfig {
        http_addr: Some("127.0.0.1:0".to_string()),
        poll_interval: None,
        artifacts_root: std::env::temp_dir(),
        models: Vec::new(),
        ..Default::default()
    })?;
    for version in [1u64, 2] {
        server.avm().basic().load_and_wait(
            ServableId::new("syn", version),
            synthetic_loader(ArtifactSpec::synthetic_multi_head("syn", version, 8, 3)),
            Duration::from_secs(30),
        )?;
    }
    // Label v2 as canary through the admin surface (same core).
    match server.core().handle(Request::SetVersionLabel {
        model: "syn".into(),
        label: "canary".into(),
        version: 2,
    }) {
        Response::Ack => {}
        other => anyhow::bail!("set label failed: {other:?}"),
    }
    let addr = server.http_addr().unwrap().to_string();
    println!("REST gateway on http://{addr}");
    let mut c = HttpClient::connect(&addr)?;

    // 2. Predict, row format: one entry per batch row.
    let body = r#"{"instances": [[0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]]}"#;
    let (status, reply) = c.post_json("/v1/models/syn:predict", body)?;
    show("POST", "/v1/models/syn:predict", Some(body), status, &reply);

    // 3. Predict, column format: named tensors in, tensors out.
    let body = r#"{"inputs": {"x": [[1, 1, 1, 1, 1, 1, 1, 1]]}}"#;
    let (status, reply) = c.post_json("/v1/models/syn:predict", body)?;
    show("POST", "/v1/models/syn:predict", Some(body), status, &reply);

    // 4. Labeled addressing: the canary label resolves to v2.
    let body = r#"{"instances": [[0, 0, 0, 0, 0, 0, 0, 0]]}"#;
    let (status, reply) = c.post_json("/v1/models/syn/labels/canary:predict", body)?;
    show("POST", "/v1/models/syn/labels/canary:predict", Some(body), status, &reply);

    // 5. Classify and regress over canonical examples.
    let body = r#"{"examples": [{"x": [0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5]}], "signature_name": "classify"}"#;
    let (status, reply) = c.post_json("/v1/models/syn:classify", body)?;
    show("POST", "/v1/models/syn:classify", Some(body), status, &reply);
    let body = r#"{"examples": [{"x": [0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5, 0.5]}], "signature_name": "regress"}"#;
    let (status, reply) = c.post_json("/v1/models/syn:regress", body)?;
    show("POST", "/v1/models/syn:regress", Some(body), status, &reply);

    // 6. Model status: per-version state, labels, signatures.
    let (status, reply) = c.get("/v1/models/syn")?;
    show("GET", "/v1/models/syn", None, status, &reply);

    // 7. Retire the canary label.
    let (status, reply) = c.delete("/v1/models/syn/labels/canary")?;
    show("DELETE", "/v1/models/syn/labels/canary", None, status, &reply);

    // 8. Metrics: first lines of the exposition.
    let (status, reply) = c.get("/metrics")?;
    let text = String::from_utf8_lossy(&reply);
    println!("\n$ curl localhost:8501/metrics   ({status})");
    for line in text.lines().filter(|l| l.contains("http_requests") || l.contains("batch_rows_count")) {
        println!("  {line}");
    }

    server.stop();
    Ok(())
}
