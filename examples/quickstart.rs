//! Quickstart: the smallest complete use of the library.
//!
//! Loads the AOT-compiled MLP classifier through a `BasicManager`,
//! fetches a handle the way an RPC handler would (§2.2), runs a few
//! predictions, and shows version-aware lookups.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use std::time::Duration;
use tensorserve::base::loader::Loader;
use tensorserve::base::servable::ServableId;
use tensorserve::base::tensor::Tensor;
use tensorserve::inference::predict::{predict, PredictRequest};
use tensorserve::inference::ModelSpec;
use tensorserve::lifecycle::basic_manager::BasicManager;
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root};
use tensorserve::runtime::hlo_servable::HloLoader;
use tensorserve::runtime::pjrt::XlaRuntime;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }

    // 1. A PJRT runtime and a manager.
    let runtime = XlaRuntime::cpu()?;
    let manager = BasicManager::with_defaults();

    // 2. Load two versions of the classifier (v2 is better trained).
    for version in [1u64, 2] {
        let dir = default_artifacts_root()
            .join("mlp_classifier")
            .join(version.to_string());
        manager.load_and_wait(
            ServableId::new("mlp_classifier", version),
            Arc::new(HloLoader::new(Arc::clone(&runtime), dir)) as Arc<dyn Loader>,
            Duration::from_secs(120),
        )?;
        println!("loaded mlp_classifier:{version}");
    }
    println!("ready versions: {:?}", manager.ready_versions("mlp_classifier"));

    // 3. Serve: latest version by default, named input against the
    //    default serving signature, named outputs back.
    let input = Tensor::matrix(vec![
        (0..32).map(|j| (j as f32 * 0.3).sin()).collect(),
        (0..32).map(|j| (j as f32 * 0.7).cos()).collect(),
    ])?;
    let resp = predict(
        manager.as_ref(),
        &PredictRequest {
            spec: ModelSpec::latest("mlp_classifier"),
            signature: String::new(), // = "serving_default"
            inputs: vec![("x".into(), input.clone())],
        },
    )?;
    println!(
        "served by version {}, classes = {:?}",
        resp.model_version,
        resp.output("class")?.as_i32()?.data()
    );
    assert_eq!(resp.model_version, 2);

    // 4. Pin an explicit version (what a rollback would serve) — the
    //    legacy single-tensor constructor still works.
    let resp1 = predict(
        manager.as_ref(),
        &PredictRequest::single("mlp_classifier", Some(1), input),
    )?;
    println!(
        "served by version {}, classes = {:?}",
        resp1.model_version,
        resp1.output("class")?.as_i32()?.data()
    );
    assert_eq!(resp1.model_version, 1);

    // 5. Unload v1; handles already checked out keep working, new
    //    lookups see only v2.
    manager.unload_and_wait(ServableId::new("mlp_classifier", 1), Duration::from_secs(30))?;
    println!("after unload: {:?}", manager.ready_versions("mlp_classifier"));
    println!("quickstart OK");
    Ok(())
}
