//! END-TO-END driver: every layer composed on a real workload.
//!
//! Python (build time) trained the MLPs and AOT-lowered them — through
//! the Pallas dense kernel — to HLO text; this binary loads them via
//! PJRT, serves them through the full lifecycle + RPC stack, and
//! reports the serving metrics the paper cares about:
//!
//! 1. RPC serving throughput + latency percentiles (closed loop).
//! 2. Latency under a fixed-rate open loop (queueing included).
//! 3. Inter-request batching (§2.2.1): concurrent single-row callers
//!    merged into device batches — throughput with vs without batching.
//! 4. Model quality over the served path (regressor correlation vs the
//!    analytic target; classifier v1/v2 agreement).
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```text
//! cargo run --release --example e2e_serving
//! ```

use std::sync::Arc;
use std::time::Duration;
use tensorserve::base::tensor::Tensor;
use tensorserve::batching::scheduler::{QueueOptions, SchedulerOptions, SharedBatchScheduler};
use tensorserve::batching::session::{BatchRunner, BatchingSession, SessionOptions};
use tensorserve::inference::example::{Example, Feature};
use tensorserve::lifecycle::basic_manager::VersionRequest;
use tensorserve::rpc::client::RpcClient;
use tensorserve::rpc::proto::{Request, Response};
use tensorserve::runtime::artifacts::{artifacts_available, default_artifacts_root};
use tensorserve::runtime::hlo_servable::HloServable;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::{ModelConfig, ServerConfig};
use tensorserve::sim::workload::{closed_loop, open_loop};
use tensorserve::util::rng::Rng;

fn gaussian_example(rng: &mut Rng) -> Example {
    let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
    Example::new().with("x", Feature::Floats(x))
}

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("artifacts missing — run `make artifacts` first");
    }
    let artifacts = default_artifacts_root();
    let model = |name: &str| ModelConfig {
        name: name.into(),
        platform: "hlo".into(),
        base_path: artifacts.join(name),
        policy: tensorserve::lifecycle::source::ServingPolicy::Latest(2),
    };

    println!("=== e2e_serving: full-stack serving run ===");
    let server = ModelServer::start(ServerConfig {
        models: vec![model("mlp_classifier"), model("mlp_regressor")],
        poll_interval: Some(Duration::from_millis(100)),
        load_threads: 4,
        ..Default::default()
    })?;
    let ready = server.wait_until_ready(Duration::from_secs(300))?;
    println!("models ready: {ready:?}");
    let addr = server.addr().to_string();

    // ---------------------------------------------------------------
    // 1. Closed-loop RPC throughput (8 clients, classify batch of 4).
    // ---------------------------------------------------------------
    {
        let addr = addr.clone();
        let stats = closed_loop(8, Duration::from_secs(4), move |tid| {
            thread_local! {
                static CLIENT: std::cell::RefCell<Option<RpcClient>> =
                    const { std::cell::RefCell::new(None) };
            }
            CLIENT.with(|c| {
                let mut c = c.borrow_mut();
                if c.is_none() {
                    *c = Some(RpcClient::connect(&addr)?);
                }
                let mut rng = Rng::new(tid as u64 * 7919);
                let examples: Vec<Example> =
                    (0..4).map(|_| gaussian_example(&mut rng)).collect();
                let resp = c
                    .as_mut()
                    .unwrap()
                    .call_ok(&Request::classify("mlp_classifier", None, examples))?;
                anyhow::ensure!(matches!(resp, Response::Classify { .. }));
                Ok(())
            })
        });
        println!("[1] closed-loop RPC classify(b=4): {}", stats.summary());
    }

    // ---------------------------------------------------------------
    // 2. Open-loop latency at a moderate fixed rate.
    // ---------------------------------------------------------------
    {
        let addr = addr.clone();
        let stats = open_loop(300.0, Duration::from_secs(4), 8, 42, move || {
            let mut client = RpcClient::connect(&addr)?;
            let mut rng = Rng::new(1);
            let resp = client.call_ok(&Request::regress(
                "mlp_regressor",
                None,
                vec![gaussian_example(&mut rng)],
            ))?;
            anyhow::ensure!(matches!(resp, Response::Regress { .. }));
            Ok(())
        });
        println!("[2] open-loop RPC regress @300qps: {}", stats.summary());
    }

    // ---------------------------------------------------------------
    // 3. Inter-request batching: 16 concurrent single-row callers.
    // ---------------------------------------------------------------
    {
        let handle = Arc::new(
            server
                .avm()
                .handle::<HloServable>("mlp_classifier", VersionRequest::Latest)?,
        );
        // The device has 2 concurrent streams (like a GPU/TPU with a
        // small number of execution queues — the regime §2.2.1 batches
        // for). A counting semaphore models the stream limit.
        struct Sem(std::sync::Mutex<usize>, std::sync::Condvar);
        impl Sem {
            fn run<T>(&self, f: impl FnOnce() -> T) -> T {
                let mut n = self.0.lock().unwrap();
                while *n == 0 {
                    n = self.1.wait(n).unwrap();
                }
                *n -= 1;
                drop(n);
                let out = f();
                *self.0.lock().unwrap() += 1;
                self.1.notify_one();
                out
            }
        }
        let sem = Arc::new(Sem(std::sync::Mutex::new(2), std::sync::Condvar::new()));

        // (a) Unbatched baseline: 16 callers each running b=1 requests
        //     through the 2-stream device.
        let h = Arc::clone(&handle);
        let sem_a = Arc::clone(&sem);
        let unbatched = closed_loop(16, Duration::from_secs(3), move |tid| {
            let mut rng = Rng::new(tid as u64);
            let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
            let t = Tensor::matrix(vec![x])?;
            sem_a.run(|| h.run(&t))?;
            Ok(())
        });

        // (b) Batched: same callers through a BatchingSession.
        let scheduler = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 2,
            name: "e2e".into(),
        });
        let h = Arc::clone(&handle);
        let sem_b = Arc::clone(&sem);
        let runner = Arc::new(move |input: Tensor| sem_b.run(|| h.run(&input)))
            as Arc<dyn BatchRunner>;
        let session = Arc::new(BatchingSession::new(
            &scheduler,
            "mlp_classifier",
            SessionOptions {
                // 16 concurrent callers ⇒ a full batch of 16 closes
                // immediately; the timeout only pads the stragglers.
                queue: QueueOptions {
                    max_batch_size: 16,
                    batch_timeout: Duration::from_micros(200),
                    max_enqueued_batches: 256,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![1, 4, 16, 64],
                ..Default::default()
            },
            runner,
        ));
        let s = Arc::clone(&session);
        let batched = closed_loop(16, Duration::from_secs(3), move |tid| {
            let mut rng = Rng::new(tid as u64);
            let x: Vec<f32> = (0..32).map(|_| rng.normal() as f32).collect();
            s.run(Tensor::matrix(vec![x])?)?;
            Ok(())
        });
        let merged = session.tasks_processed() as f64
            / session.batches_processed().max(1) as f64;
        println!(
            "[3] batching: unbatched {:.0} qps vs batched {:.0} qps \
             (mean merged batch {merged:.1}; speedup {:.2}x)",
            unbatched.qps(),
            batched.qps(),
            batched.qps() / unbatched.qps()
        );
        println!("    unbatched latency {}", unbatched.latency.summary());
        println!("    batched   latency {}", batched.latency.summary());
    }

    // ---------------------------------------------------------------
    // 4. Served-model quality.
    // ---------------------------------------------------------------
    {
        let mut client = RpcClient::connect(&addr)?;
        let mut rng = Rng::new(99);
        let examples: Vec<Example> = (0..256).map(|_| gaussian_example(&mut rng)).collect();
        let targets: Vec<f32> = examples
            .iter()
            .map(|e| {
                let x = e.floats("x").unwrap();
                x[0].tanh() + 0.5 * x[1] * x[2]
            })
            .collect();
        // Chunk to the largest compiled batch size (the ladder tops at
        // 64; bigger requests would go through the splitter).
        let mut values = Vec::new();
        for chunk in examples.chunks(64) {
            let resp =
                client.call_ok(&Request::regress("mlp_regressor", None, chunk.to_vec()))?;
            match resp {
                Response::Regress { values: v, .. } => values.extend(v),
                other => anyhow::bail!("unexpected {other:?}"),
            }
        }
        let n = values.len() as f32;
        let (mp, mt) = (
            values.iter().sum::<f32>() / n,
            targets.iter().sum::<f32>() / n,
        );
        let cov: f32 = values.iter().zip(&targets).map(|(p, t)| (p - mp) * (t - mt)).sum();
        let vp: f32 = values.iter().map(|p| (p - mp) * (p - mp)).sum();
        let vt: f32 = targets.iter().map(|t| (t - mt) * (t - mt)).sum();
        let corr = cov / (vp.sqrt() * vt.sqrt());
        println!("[4] served regressor correlation vs analytic target: r={corr:.3}");
        anyhow::ensure!(corr > 0.6, "served model quality collapsed");

        // classifier v1/v2 agreement over the served path
        let agree = {
            let c1 = client.call_ok(&Request::classify(
                "mlp_classifier",
                Some(1),
                examples[..64].to_vec(),
            ))?;
            let c2 = client.call_ok(&Request::classify(
                "mlp_classifier",
                Some(2),
                examples[..64].to_vec(),
            ))?;
            match (c1, c2) {
                (
                    Response::Classify { classes: a, .. },
                    Response::Classify { classes: b, .. },
                ) => a.iter().zip(&b).filter(|(x, y)| x == y).count(),
                _ => 0,
            }
        };
        println!("[4] classifier v1/v2 agreement on 64 samples: {agree}/64");
    }

    println!("server metrics:\n{}", server.registry().dump());
    server.stop();
    println!("e2e_serving OK");
    Ok(())
}
