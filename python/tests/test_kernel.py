"""L1 correctness: Pallas dense kernel vs the pure-jnp oracle.

This is the CORE build-time correctness signal: hypothesis sweeps shapes,
dtypes, activations and block sizes; every case must match ref.py to
tight tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as dk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("activation", dk.ACTIVATIONS)
@pytest.mark.parametrize(
    "b,k,n",
    [(1, 32, 4), (4, 32, 4), (16, 64, 64), (64, 32, 1), (8, 128, 128)],
)
def test_dense_matches_ref_serving_shapes(b, k, n, activation):
    """The exact shapes the AOT models use."""
    x, w, bias = rand(0, (b, k), jnp.float32), rand(1, (k, n), jnp.float32), rand(
        2, (n,), jnp.float32
    )
    got = dk.dense(x, w, bias, activation=activation)
    want = ref.dense_ref(x, w, bias, activation=activation)
    np.testing.assert_allclose(got, want, **tol(jnp.float32))


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 70),
    k=st.integers(1, 130),
    n=st.integers(1, 140),
    activation=st.sampled_from(dk.ACTIVATIONS),
    seed=st.integers(0, 2**16),
)
def test_dense_matches_ref_fuzzed_shapes(b, k, n, activation, seed):
    """Arbitrary (incl. non-block-multiple) shapes must pad correctly."""
    x = rand(seed, (b, k), jnp.float32)
    w = rand(seed + 1, (k, n), jnp.float32)
    bias = rand(seed + 2, (n,), jnp.float32)
    got = dk.dense(x, w, bias, activation=activation)
    want = ref.dense_ref(x, w, bias, activation=activation)
    assert got.shape == (b, n)
    np.testing.assert_allclose(got, want, **tol(jnp.float32))


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 20),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_dense_bfloat16(b, k, n, seed):
    """bf16 inputs (the MXU-native dtype) accumulate in f32 like ref."""
    x = rand(seed, (b, k), jnp.bfloat16)
    w = rand(seed + 1, (k, n), jnp.bfloat16)
    bias = rand(seed + 2, (n,), jnp.bfloat16)
    got = dk.dense(x, w, bias, activation="relu")
    want = ref.dense_ref(x, w, bias, activation="relu")
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), **tol(jnp.bfloat16)
    )


@pytest.mark.parametrize("block_b,block_n", [(8, 128), (16, 128), (8, 256)])
def test_dense_block_size_invariance(block_b, block_n):
    """Tiling is a schedule, not semantics: results identical across blocks."""
    x, w, bias = rand(5, (24, 48), jnp.float32), rand(6, (48, 200), jnp.float32), rand(
        7, (200,), jnp.float32
    )
    base = ref.dense_ref(x, w, bias, activation="tanh")
    got = dk.dense(x, w, bias, activation="tanh", block_b=block_b, block_n=block_n)
    np.testing.assert_allclose(got, base, **tol(jnp.float32))


def test_dense_rejects_bad_shapes():
    x, w, b = jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros((5,))
    with pytest.raises(ValueError):
        dk.dense(x, w, b)
    with pytest.raises(ValueError):
        dk.dense(jnp.zeros((2, 4)), jnp.zeros((4, 5)), jnp.zeros((6,)))
    with pytest.raises(ValueError):
        dk.dense(jnp.zeros((2, 4)), jnp.zeros((4, 5)), jnp.zeros((5,)), activation="gelu")


def test_vmem_footprint_fits_tpu_budget():
    """DESIGN.md §Perf: one grid step must fit comfortably in 16 MiB VMEM."""
    for k in (32, 64, 128, 512):
        assert dk.vmem_footprint_bytes(k) < 2 * 1024 * 1024


def test_mxu_utilization_estimate():
    assert dk.mxu_utilization_estimate(8, 64, 128) == 1.0
    # Batch 1 against an 8-row block wastes 7/8 of issued sublanes.
    assert abs(dk.mxu_utilization_estimate(1, 64, 128) - 1 / 8) < 1e-9
    assert 0 < dk.mxu_utilization_estimate(3, 64, 100) < 1.0
