"""L2 tests: model forward shapes, kernel-vs-ref parity at the model level,
training improves quality (v2 > v1 premise of the canary example)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = m.MlpConfig(input_dim=16, hidden_dims=(24, 24), output_dim=3, name="t")


def test_init_params_shapes():
    params = m.init_params(CFG, jax.random.PRNGKey(0))
    assert [(w.shape, b.shape) for w, b in params] == [
        ((16, 24), (24,)),
        ((24, 24), (24,)),
        ((24, 3), (3,)),
    ]


@pytest.mark.parametrize("batch", [1, 4, 7, 16])
def test_mlp_kernel_matches_ref(batch):
    params = m.init_params(CFG, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (batch, CFG.input_dim))
    got = m.mlp_forward(params, x, use_kernel=True)
    want = ref.mlp_ref(x, params)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_classifier_forward_outputs():
    params = m.init_params(CFG, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (5, CFG.input_dim))
    log_probs, pred = m.classifier_forward(params, x)
    assert log_probs.shape == (5, 3) and pred.shape == (5,)
    assert pred.dtype == jnp.int32
    # log-probs rows sum to 1 in prob space
    np.testing.assert_allclose(
        jnp.exp(log_probs).sum(axis=-1), np.ones(5), rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(pred), np.argmax(log_probs, axis=-1))


def test_regressor_forward_outputs():
    cfg = m.MlpConfig(input_dim=16, hidden_dims=(8,), output_dim=1, name="r")
    params = m.init_params(cfg, jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (9, cfg.input_dim))
    (value,) = m.regressor_forward(params, x)
    assert value.shape == (9,)


def test_training_improves_classifier():
    """The v1/v2 canary premise: more steps -> materially better accuracy."""
    _, acc_short = m.train_classifier(CFG, steps=5, seed=0)
    _, acc_long = m.train_classifier(CFG, steps=200, seed=0)
    assert acc_long > acc_short
    assert acc_long > 0.9


def test_training_improves_regressor():
    cfg = m.MlpConfig(input_dim=8, hidden_dims=(16,), output_dim=1, name="r")
    _, mse_short = m.train_regressor(cfg, steps=5)
    _, mse_long = m.train_regressor(cfg, steps=300)
    assert mse_long < mse_short


def test_blobs_are_learnable_data():
    x, y = m.make_blobs(jax.random.PRNGKey(7), 256, CFG)
    assert x.shape == (256, CFG.input_dim) and y.shape == (256,)
    assert int(y.min()) >= 0 and int(y.max()) < CFG.output_dim
    assert len(set(np.asarray(y).tolist())) == CFG.output_dim
