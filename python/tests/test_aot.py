"""AOT pipeline tests: lowered HLO text is parseable-looking, artifacts
have the layout the rust FileSystemSource/HloSourceAdapter consume, and
spec.json carries everything the runtime needs."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as m

jax.config.update("jax_platform_name", "cpu")

CFG = m.MlpConfig(input_dim=8, hidden_dims=(8,), output_dim=2, name="tiny")


def test_lower_servable_emits_hlo_text():
    params = m.init_params(CFG, jax.random.PRNGKey(0))
    hlo = aot.lower_servable(m.classifier_forward, params, CFG.input_dim, 4)
    assert "HloModule" in hlo
    # weights are baked in as constants -> the ENTRY computation takes
    # exactly one parameter (x). (Sub-computations may have their own.)
    # (in HLO text, sub-computations precede ENTRY, so everything after
    # the ENTRY line is the entry body)
    entry_body = hlo[hlo.index("ENTRY") :]
    assert entry_body.count(" parameter(") == 1, entry_body
    # REGRESSION GATE: default HLO printing elides large constants as
    # `{...}`, which the rust-side parser reparses as ZEROS (weights
    # vanish silently). to_hlo_text must print full constants.
    assert "{...}" not in hlo
    # and metadata must be stripped (xla_extension 0.5.1 parser rejects
    # modern attributes like source_end_line)
    assert "metadata=" not in hlo
    # fixed batch shape appears
    assert "f32[4,8]" in hlo


def test_lower_servable_batch_sizes_differ():
    params = m.init_params(CFG, jax.random.PRNGKey(0))
    h1 = aot.lower_servable(m.classifier_forward, params, CFG.input_dim, 1)
    h16 = aot.lower_servable(m.classifier_forward, params, CFG.input_dim, 16)
    assert "f32[1,8]" in h1 and "f32[16,8]" in h16


def test_write_model_layout(tmp_path):
    params = m.init_params(CFG, jax.random.PRNGKey(1))
    aot.write_model(
        str(tmp_path),
        "tiny",
        3,
        m.classifier_forward,
        params,
        CFG,
        signature="classify",
        outputs=[{"name": "log_probs", "shape": [-1, 2], "dtype": "f32"}],
        metrics={"train_steps": 0},
    )
    vdir = tmp_path / "tiny" / "3"
    for b in aot.ALLOWED_BATCH_SIZES:
        assert (vdir / f"model_b{b}.hlo.txt").exists()
    spec = json.loads((vdir / "spec.json").read_text())
    assert spec["platform"] == "hlo"
    assert spec["signature"] == "classify"
    assert spec["version"] == 3
    assert spec["allowed_batch_sizes"] == list(aot.ALLOWED_BATCH_SIZES)
    assert spec["input"]["shape"] == [-1, CFG.input_dim]
    assert spec["ram_estimate_bytes"] > 0
    assert spec["n_params"] == sum(w.size + b_.size for w, b_ in params)


def test_write_toy_table_layout(tmp_path):
    aot.write_toy_table(str(tmp_path))
    table = json.loads((tmp_path / "toy_table" / "1" / "table.json").read_text())
    assert table["platform"] == "table"
    assert len(table["entries"]) == 100
    assert table["entries"]["3"] == [3.0, 2.0]


def test_repo_artifacts_if_built():
    """When `make artifacts` has run, validate the real tree."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    marker = os.path.join(root, "mlp_classifier")
    if not os.path.isdir(marker):
        pytest.skip("artifacts not built yet")
    for version in aot.CLASSIFIER_VERSIONS:
        vdir = os.path.join(marker, str(version))
        spec = json.load(open(os.path.join(vdir, "spec.json")))
        assert spec["signature"] == "classify"
        for b in spec["allowed_batch_sizes"]:
            assert os.path.exists(os.path.join(vdir, f"model_b{b}.hlo.txt"))
    # v2 must actually be better than v1 (canary premise)
    s1 = json.load(open(os.path.join(marker, "1", "spec.json")))
    s2 = json.load(open(os.path.join(marker, "2", "spec.json")))
    assert s2["metrics"]["train_accuracy"] >= s1["metrics"]["train_accuracy"]
