"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the kernels are validated against at build
time (python/tests/test_kernel.py). Keep them boring: no pallas, no
custom tiling — just the textbook math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "linear"
) -> jax.Array:
    """Reference ``act(x @ w + b)`` with f32 accumulation."""
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b.astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "tanh":
        acc = jnp.tanh(acc)
    elif activation != "linear":
        raise ValueError(f"unknown activation {activation!r}")
    return acc.astype(x.dtype)


def mlp_ref(x, params):
    """Reference MLP forward: relu hidden layers, linear final layer."""
    h = x
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        h = dense_ref(h, w, b, activation="linear" if last else "relu")
    return h
