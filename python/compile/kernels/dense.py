"""L1: fused dense-layer Pallas kernel — the inference hot-spot.

The paper's batching machinery (TF-Serving §2.2.1) exists to feed exactly
this kind of kernel: a *merged* batch of requests streamed through the
accelerator's matrix unit. We implement ``y = act(x @ W + b)`` as a Pallas
kernel tiled for the TPU memory hierarchy:

* grid = (batch tiles, output tiles); each program owns a
  ``(BLOCK_B, BLOCK_N)`` output tile resident in VMEM,
* the reduction dimension K is kept whole per tile (models here have
  K <= 512, so an x-tile of BLOCK_B*K f32 and a W-tile of K*BLOCK_N f32
  both fit VMEM comfortably — see DESIGN.md §Perf for the footprint math),
* the inner ``jnp.dot`` maps onto the MXU systolic array on real TPUs
  (bf16/f32); under ``interpret=True`` it runs as numpy on CPU, which is
  the only mode the CPU PJRT plugin can execute (real TPU lowering emits a
  Mosaic custom-call).

Correctness oracle: ``kernels.ref.dense_ref`` (pure jnp), enforced by
``python/tests/test_kernel.py`` (hypothesis sweeps shapes/dtypes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile shape. 128 matches the MXU lane width; BLOCK_B rides
# the sublane dimension. (8, 128) * 4B = 4 KiB per f32 output tile.
BLOCK_B = 8
BLOCK_N = 128

ACTIVATIONS = ("linear", "relu", "tanh")


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One (BLOCK_B, BLOCK_N) output tile: full-K matmul + bias + act."""
    x = x_ref[...]  # (BLOCK_B, K)      VMEM
    w = w_ref[...]  # (K, BLOCK_N)      VMEM
    b = b_ref[...]  # (1, BLOCK_N)      VMEM
    # MXU-shaped contraction; accumulate in f32 regardless of input dtype.
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)
    acc = acc + b.astype(jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "tanh":
        acc = jnp.tanh(acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("activation", "block_b", "block_n", "interpret")
)
def dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "linear",
    block_b: int = BLOCK_B,
    block_n: int = BLOCK_N,
    interpret: bool = True,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` via a Pallas kernel.

    x: (B, K), w: (K, N), b: (N,). Returns (B, N) in x.dtype.
    Shapes that are not multiples of the block sizes are zero-padded into
    the grid and sliced back out (zero rows/cols do not perturb the valid
    region of a matmul; bias/activation are elementwise).
    """
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    if x.ndim != 2 or w.ndim != 2 or b.ndim != 1:
        raise ValueError(f"bad ranks: x{x.shape} w{w.shape} b{b.shape}")
    B, K = x.shape
    K2, N = w.shape
    if K != K2 or b.shape[0] != N:
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    bb = min(block_b, _ceil_to(B, 8))
    bn = min(block_n, _ceil_to(N, 128))
    Bp, Np = _ceil_to(B, bb), _ceil_to(N, bn)
    xp = jnp.pad(x, ((0, Bp - B), (0, 0))) if Bp != B else x
    wp = jnp.pad(w, ((0, 0), (0, Np - N))) if Np != N else w
    bp = (jnp.pad(b, (0, Np - N)) if Np != N else b).reshape(1, Np)

    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=(Bp // bb, Np // bn),
        in_specs=[
            pl.BlockSpec((bb, K), lambda i, j: (i, 0)),   # x tile: row band
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),   # w tile: col band
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),   # bias tile
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:B, :N]


def vmem_footprint_bytes(
    k: int, dtype_bytes: int = 4, block_b: int = BLOCK_B, block_n: int = BLOCK_N
) -> int:
    """Static VMEM footprint of one grid step (see DESIGN.md §Perf)."""
    x_tile = block_b * k * dtype_bytes
    w_tile = k * block_n * dtype_bytes
    b_tile = block_n * dtype_bytes
    o_tile = block_b * block_n * dtype_bytes
    return x_tile + w_tile + b_tile + o_tile


def mxu_utilization_estimate(
    b: int, k: int, n: int, block_b: int = BLOCK_B, block_n: int = BLOCK_N
) -> float:
    """Fraction of MXU-issued MACs that are useful work (non-padding)."""
    useful = b * k * n
    issued = _ceil_to(b, block_b) * k * _ceil_to(n, block_n)
    return useful / issued
