"""AOT compile path: lower the L2 JAX models to HLO *text* artifacts.

This is the only place Python runs — once, at build time (`make
artifacts`). The rust serving binary is self-contained afterwards.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
XLA (xla_extension 0.5.1) rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifact layout (the rust `FileSystemSource` + `HloSourceAdapter` consume
exactly this):

    artifacts/
      <model_name>/
        <version>/                  # numeric version dirs, larger = newer
          model_b<N>.hlo.txt        # one fixed-shape module per allowed
          ...                       #   batch size N (TPU-style static shapes)
          spec.json                 # signature, shapes, batch sizes, metrics
      toy_table/1/table.json        # a "BananaFlow" (non-HLO) servable

Fixed-shape executables per allowed batch size mirror what a TPU serving
deployment does; the rust batcher pads each merged batch up to the
nearest allowed size (batching/padding.rs).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as m

ALLOWED_BATCH_SIZES = (1, 4, 16, 64)

CLASSIFIER_CONFIG = m.MlpConfig(
    input_dim=32, hidden_dims=(64, 64), output_dim=4, name="mlp_classifier"
)
REGRESSOR_CONFIG = m.MlpConfig(
    input_dim=32, hidden_dims=(64, 64), output_dim=1, name="mlp_regressor"
)

# version -> training steps. v2 is trained ~10x longer than v1, so canary
# comparisons in the rust examples observe a real quality difference.
CLASSIFIER_VERSIONS = {1: 5, 2: 300}
REGRESSOR_VERSIONS = {1: 100, 2: 1500}


def to_hlo_text(lowered) -> str:
    """jax lowering -> XlaComputation -> HLO text (see module docstring).

    CRITICAL: default HLO printing *elides* large constants as `{...}`,
    which the text parser silently reparses as zeros — with weights baked
    in as constants that made every model output bias-only garbage. Print
    via HloPrintOptions with print_large_constants=True.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # Modern metadata attributes (source_end_line etc.) are rejected by
    # xla_extension 0.5.1's HLO parser — strip metadata entirely.
    opts.print_metadata = False
    text = comp.as_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived printing"
    return text


def lower_servable(forward, params, input_dim: int, batch: int) -> str:
    """Lower `forward(params, x)` with params *baked in as constants*."""
    fn = functools.partial(forward, params)  # close over weights
    spec = jax.ShapeDtypeStruct((batch, input_dim), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def write_model(
    out_dir: str,
    name: str,
    version: int,
    forward,
    params,
    config: m.MlpConfig,
    signature: str,
    outputs,
    metrics,
) -> None:
    vdir = os.path.join(out_dir, name, str(version))
    os.makedirs(vdir, exist_ok=True)
    for b in ALLOWED_BATCH_SIZES:
        hlo = lower_servable(forward, params, config.input_dim, b)
        with open(os.path.join(vdir, f"model_b{b}.hlo.txt"), "w") as f:
            f.write(hlo)
    n_params = sum(w.size + b.size for w, b in params)
    spec = {
        "platform": "hlo",
        "signature": signature,
        "model_name": name,
        "version": version,
        "input": {"name": "x", "shape": [-1, config.input_dim], "dtype": "f32"},
        "outputs": outputs,
        "allowed_batch_sizes": list(ALLOWED_BATCH_SIZES),
        "artifact_pattern": "model_b{batch}.hlo.txt",
        "n_params": int(n_params),
        # RAM estimate the TFS^2 Controller uses for bin-packing: params
        # + per-executable compiled-module overhead (coarse, like the paper).
        "ram_estimate_bytes": int(n_params * 4 * 3 + (1 << 20)),
        "metrics": metrics,
    }
    with open(os.path.join(vdir, "spec.json"), "w") as f:
        json.dump(spec, f, indent=2)
    write_golden(vdir, forward, params, config)
    print(f"  wrote {name}/{version} ({n_params} params, {metrics})")


def write_golden(vdir: str, forward, params, config: m.MlpConfig) -> None:
    """Golden predictions for cross-layer numerics parity.

    rust/tests/numerics_parity.rs replays these inputs through the
    AOT-compiled HLO on the PJRT CPU client and asserts the outputs
    match what jax computed here. This is the gate that caught the
    elided-large-constants bug (weights silently reparsed as zeros).
    """
    import numpy as np

    rng = np.random.default_rng(20260711)
    inputs = rng.standard_normal((4, config.input_dim)).astype(np.float32)
    outputs = forward(params, jnp.asarray(inputs))
    golden = {
        "inputs": [[float(v) for v in row] for row in inputs],
        "outputs": [
            {
                "dtype": str(o.dtype),
                "values": np.asarray(o).reshape(-1).astype(float).tolist(),
                "shape": list(o.shape),
            }
            for o in outputs
        ],
    }
    with open(os.path.join(vdir, "golden.json"), "w") as f:
        json.dump(golden, f)


def write_toy_table(out_dir: str) -> None:
    """A non-HLO servable ("BananaFlow"): an embedding lookup table."""
    vdir = os.path.join(out_dir, "toy_table", "1")
    os.makedirs(vdir, exist_ok=True)
    table = {
        "platform": "table",
        "model_name": "toy_table",
        "version": 1,
        "entries": {str(i): [float(i), float(i * i % 7)] for i in range(100)},
    }
    with open(os.path.join(vdir, "table.json"), "w") as f:
        json.dump(table, f, indent=2)
    print("  wrote toy_table/1")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    out = args.out

    print("training + lowering classifier versions...")
    for version, steps in CLASSIFIER_VERSIONS.items():
        params, acc = m.train_classifier(CLASSIFIER_CONFIG, steps)
        write_model(
            out,
            CLASSIFIER_CONFIG.name,
            version,
            m.classifier_forward,
            params,
            CLASSIFIER_CONFIG,
            signature="classify",
            outputs=[
                {"name": "log_probs", "shape": [-1, CLASSIFIER_CONFIG.output_dim], "dtype": "f32"},
                {"name": "class", "shape": [-1], "dtype": "s32"},
            ],
            metrics={"train_steps": steps, "train_accuracy": round(acc, 4)},
        )

    print("training + lowering regressor versions...")
    for version, steps in REGRESSOR_VERSIONS.items():
        params, mse = m.train_regressor(REGRESSOR_CONFIG, steps)
        write_model(
            out,
            REGRESSOR_CONFIG.name,
            version,
            m.regressor_forward,
            params,
            REGRESSOR_CONFIG,
            signature="regress",
            outputs=[{"name": "value", "shape": [-1], "dtype": "f32"}],
            metrics={"train_steps": steps, "train_mse": round(mse, 6)},
        )

    write_toy_table(out)
    print(f"artifacts written to {out}")


if __name__ == "__main__":
    main()
