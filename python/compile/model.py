"""L2: the models TensorFlow-Serving serves, written in JAX.

The paper treats models as black boxes; for the reproduction we need
concrete servables, so we define two (mirroring the paper's
classification + regression APIs, §2.2):

* ``MLPClassifier`` — dense(relu) x2 -> dense -> log-softmax scores.
* ``MLPRegressor``  — dense(relu) x2 -> dense(1) value head.

Both forward passes route every dense layer through the L1 Pallas kernel
(``kernels.dense.dense``), so the AOT-lowered HLO exercises the kernel
end-to-end. Weights are *baked into the lowered module as constants*
(closed over, not arguments): the serving request path only ships the
input tensor, matching how TF-Serving ships a frozen SavedModel.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from compile.kernels import dense as dense_kernel
from compile.kernels import ref as kernels_ref


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    """Architecture of a small MLP servable."""

    input_dim: int = 32
    hidden_dims: tuple = (64, 64)
    output_dim: int = 4  # n classes for classifier; 1 for regressor
    name: str = "mlp"

    @property
    def layer_dims(self):
        dims = (self.input_dim, *self.hidden_dims, self.output_dim)
        return list(zip(dims[:-1], dims[1:]))


def init_params(config: MlpConfig, key: jax.Array):
    """He-initialized params: list of (w, b) per layer."""
    params = []
    for k_in, k_out in config.layer_dims:
        key, wkey = jax.random.split(key)
        w = jax.random.normal(wkey, (k_in, k_out), jnp.float32) * jnp.sqrt(
            2.0 / k_in
        )
        b = jnp.zeros((k_out,), jnp.float32)
        params.append((w, b))
    return params


def mlp_forward(params, x: jax.Array, *, use_kernel: bool = True) -> jax.Array:
    """MLP logits/value via the Pallas kernel (or the jnp ref)."""
    fn = dense_kernel.dense if use_kernel else kernels_ref.dense_ref
    h = x
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        h = fn(h, w, b, activation="linear" if last else "relu")
    return h


def classifier_forward(params, x: jax.Array, *, use_kernel: bool = True):
    """Returns (log_probs, predicted_class). This is the servable fn."""
    logits = mlp_forward(params, x, use_kernel=use_kernel)
    log_probs = jax.nn.log_softmax(logits, axis=-1)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return log_probs, pred


def regressor_forward(params, x: jax.Array, *, use_kernel: bool = True):
    """Returns (value,) of shape (B,). This is the servable fn."""
    out = mlp_forward(params, x, use_kernel=use_kernel)
    return (out[:, 0],)


# ---------------------------------------------------------------------------
# Synthetic data + training (build-time only). v1 vs v2 of a servable are
# checkpoints at different training lengths, so the canary comparison in
# the rust examples sees a real quality difference.
# ---------------------------------------------------------------------------


def make_blobs(key, n: int, config: MlpConfig, *, noise: float = 2.5):
    """Gaussian blobs: one cluster per class, linearly separable-ish."""
    kc, kx, ky = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (config.output_dim, config.input_dim)) * 3.0
    labels = jax.random.randint(ky, (n,), 0, config.output_dim)
    x = centers[labels] + noise * jax.random.normal(kx, (n, config.input_dim))
    return x.astype(jnp.float32), labels


def make_regression_data(key, n: int, config: MlpConfig, *, noise: float = 0.05):
    """y = tanh(x0) + 0.5*x1*x2 + eps — smooth, nonlinear, high-variance."""
    kx, ke = jax.random.split(key)
    x = jax.random.normal(kx, (n, config.input_dim), jnp.float32)
    y = jnp.tanh(x[:, 0]) + 0.5 * x[:, 1] * x[:, 2]
    y = y + noise * jax.random.normal(ke, (n,))
    return x, y.astype(jnp.float32)


def _sgd(params, grads, lr):
    return [(w - lr * gw, b - lr * gb) for (w, b), (gw, gb) in zip(params, grads)]


def train_classifier(config: MlpConfig, steps: int, seed: int = 0, lr: float = 0.05):
    """Full-batch softmax-CE training on blobs. Returns (params, accuracy)."""
    key = jax.random.PRNGKey(seed)
    kd, kp = jax.random.split(key)
    x, y = make_blobs(kd, 1024, config)
    params = init_params(config, kp)

    def loss_fn(params):
        # Train with the jnp ref (fast to trace); serve with the kernel.
        logits = mlp_forward(params, x, use_kernel=False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(logp[jnp.arange(x.shape[0]), y])

    step = jax.jit(lambda p: _sgd(p, jax.grad(loss_fn)(p), lr))
    for _ in range(steps):
        params = step(params)
    preds = jnp.argmax(mlp_forward(params, x, use_kernel=False), axis=-1)
    acc = float(jnp.mean(preds == y))
    return params, acc


def train_regressor(config: MlpConfig, steps: int, seed: int = 1, lr: float = 0.05):
    """Full-batch MSE training. Returns (params, mse)."""
    key = jax.random.PRNGKey(seed)
    kd, kp = jax.random.split(key)
    x, y = make_regression_data(kd, 1024, config)
    params = init_params(config, kp)

    def loss_fn(params):
        pred = mlp_forward(params, x, use_kernel=False)[:, 0]
        return jnp.mean((pred - y) ** 2)

    step = jax.jit(lambda p: _sgd(p, jax.grad(loss_fn)(p), lr))
    for _ in range(steps):
        params = step(params)
    mse = float(loss_fn(params))
    return params, mse
