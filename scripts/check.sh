#!/usr/bin/env sh
# Tier-1 verification gate: build, tests, and (when rustfmt is
# installed) formatting. Run via `make check` or directly.
#
#   --bench-smoke   additionally run every bench for one short
#                   iteration (TENSORSERVE_BENCH_SMOKE=1): a compile
#                   AND run guard, so benches cannot silently rot.
#                   Smoke numbers are meaningless; only completion
#                   matters.
set -eu

cd "$(dirname "$0")/.."

BENCH_SMOKE=0
for arg in "$@"; do
    case "$arg" in
        --bench-smoke) BENCH_SMOKE=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# The REST gateway's end-to-end suite, named explicitly so a gateway
# regression is visible as its own failing step.
echo "==> cargo test -q --test http_gateway"
cargo test -q --test http_gateway

# Differential codec fuzz: seeded random valid + adversarial predict
# bodies through the SIMD/SWAR fast path and the scalar JSON codec;
# results must be bit-identical (or the same error), in one shot and
# under arbitrary chunking. Named explicitly so a wire-codec
# divergence is its own failing step.
echo "==> cargo test -q --test codec_fuzz"
cargo test -q --test codec_fuzz

# Cross-request batching on the live serving path: concurrent requests
# must merge (executions < requests), unloads must drain queued work
# cleanly, and the lane-isolation guarantees (fast-model p99 bounded
# while a slow lane saturates) must hold. Named explicitly so a
# batching regression is its own failing step.
echo "==> cargo test -q --test serving_concurrency"
cargo test -q --test serving_concurrency

# Graceful degradation under injected faults: deadline-expired work
# dropped before execution (504), load shedding at the admission cap
# (503 + Retry-After), and lifecycle load retry with the old version
# serving throughout. Named explicitly so a robustness regression is
# its own failing step.
echo "==> cargo test -q --test chaos"
cargo test -q --test chaos

# The net subsystem's scaling guarantees: 1000+ keep-alive connections
# on O(reactor+worker) threads, slow-loris sweep, over-limit rejects,
# drain-on-stop, and the legacy threaded path's joined teardown. Named
# explicitly so an I/O-plane regression is its own failing step.
echo "==> cargo test -q --test net_scaling"
cargo test -q --test net_scaling

# The TFS² control plane over real sockets: Controller placement,
# Synchronizer convergence, canary/rollback, store durability, and
# hedged routing (skips model-loading cases if artifacts are absent).
# Named explicitly so a control-plane regression is its own failing
# step.
echo "==> cargo test -q --test tfs2_integration"
cargo test -q --test tfs2_integration

# Fleet end-to-end on synthetic servables (no artifacts needed):
# durable labels across a controller restart, metric-driven
# autoscaling on real lane depth, and hedged routing keeping p99
# bounded with a fault-injected slow replica.
echo "==> cargo test -q --test tfs2_fleet"
cargo test -q --test tfs2_fleet

# Health-gated rollout chaos soak: a healthy canary promotes on its
# own, a version-scoped exec fault forces an auto-rollback with the
# reason surfaced, replica breakers open and half-open-recover, and a
# stable-label client sees zero errors through version + replica
# churn. Named explicitly so a rollout regression is its own failing
# step.
echo "==> cargo test -q --test rollout_chaos"
cargo test -q --test rollout_chaos

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable in this toolchain; skipping fmt check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy -- -D warnings"
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "==> clippy unavailable in this toolchain; skipping lint"
fi

if [ "$BENCH_SMOKE" = "1" ]; then
    # Every registered bench, one short run each. bench_e2e exits
    # early (cleanly) when artifacts are missing.
    for b in bench_batching bench_throughput bench_tail_latency bench_http \
             bench_net bench_rcu bench_hedging bench_startup bench_transition \
             bench_binpack bench_e2e; do
        echo "==> bench smoke: $b"
        TENSORSERVE_BENCH_SMOKE=1 cargo bench --bench "$b"
    done
fi

echo "check OK"
