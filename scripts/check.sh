#!/usr/bin/env sh
# Tier-1 verification gate: build, tests, and (when rustfmt is
# installed) formatting. Run via `make check` or directly.
set -eu

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# The REST gateway's end-to-end suite, named explicitly so a gateway
# regression is visible as its own failing step.
echo "==> cargo test -q --test http_gateway"
cargo test -q --test http_gateway

# Cross-request batching on the live serving path: concurrent requests
# must merge (executions < requests) and unloads must drain queued
# work cleanly. Named explicitly so a batching regression is its own
# failing step.
echo "==> cargo test -q --test serving_concurrency"
cargo test -q --test serving_concurrency

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable in this toolchain; skipping fmt check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy -- -D warnings"
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "==> clippy unavailable in this toolchain; skipping lint"
fi

echo "check OK"
