#!/usr/bin/env sh
# Tier-1 verification gate: build, tests, and (when rustfmt is
# installed) formatting. Run via `make check` or directly.
set -eu

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

# The REST gateway's end-to-end suite, named explicitly so a gateway
# regression is visible as its own failing step.
echo "==> cargo test -q --test http_gateway"
cargo test -q --test http_gateway

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable in this toolchain; skipping fmt check"
fi

echo "check OK"
