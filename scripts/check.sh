#!/usr/bin/env sh
# Tier-1 verification gate: build, tests, and (when rustfmt is
# installed) formatting. Run via `make check` or directly.
set -eu

cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "==> rustfmt unavailable in this toolchain; skipping fmt check"
fi

echo "check OK"
