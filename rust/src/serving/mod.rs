//! The live serving path's cross-request batching layer (§3.3 / §2.2.1).
//!
//! PR 1 built the machinery — [`BatchingSession`]'s fused single-
//! allocation assembly, the shared scheduler, the splitter — but the
//! inference layer still called `handle.run()` directly, so concurrent
//! requests never merged into hardware-sized batches: exactly the
//! "performance pitfall of naive implementations" the paper warns
//! about. This module puts the machinery on the hot path:
//!
//! * [`Runner`] — the execution seam the inference layer goes through
//!   instead of dereferencing the servable itself. [`DirectRunner`] is
//!   the unbatched strategy (library users, tests, tools);
//!   [`SessionRegistry`] is the serving strategy.
//! * [`SessionRegistry`] — one [`BatchingSession`] per loaded
//!   `(model, version)`, created when a servable reaches `Ready` and
//!   torn down on the unload path, driven by the manager's event bus
//!   (the same hook label GC uses). Requests from **both** wire planes
//!   (binary RPC and HTTP/JSON) resolve the same session, so they
//!   merge into shared device batches; the splitter chunks oversized
//!   requests and view tensors scatter outputs back with zero copies.
//!
//! Each session is an isolated scheduler **lane**: lanes rotate
//! through the worker pool in weighted round-robin order (one model's
//! backlog cannot starve another's — the multi-tenant head-of-line
//! hazard §2.2.1 warns about), queue depth surfaces as the
//! `batch.{model}.lane_depth` gauge, and a
//! `batching.models[].dedicated_threads` override gives a
//! latency-critical model a private device-worker set that shared-lane
//! saturation can never occupy.
//!
//! Teardown is drain-by-refusal: the per-session runner is gated on a
//! `closed` flag set before the queue handle drops, so work still
//! queued when a version unloads gets a clean
//! [`ErrorKind::FailedPrecondition`] ("retry") instead of hanging or
//! racing a freed servable — the gate holds the servable handle alive
//! until the queue fully drains, and the handle's deferred-reclaim
//! drop runs only after the last queued batch was answered.

use crate::base::error::ErrorKind;
use crate::base::servable::{ServableHandle, ServableId};
use crate::base::tensor::Tensor;
use crate::batching::scheduler::{QueueOptions, SchedulerOptions, SharedBatchScheduler};
use crate::batching::session::{BatchRunner, BatchingSession, PendingRun, SessionOptions};
use crate::lifecycle::basic_manager::{BasicManager, VersionRequest};
use crate::lifecycle::harness::State;
use crate::runtime::hlo_servable::HloServable;
use crate::runtime::pjrt::OutTensor;
use crate::util::metrics::Registry;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

/// Per-request execution options carried from the wire down to the
/// batching lanes. `deadline` is absolute (stamped when the request
/// was *received*): work still unexecuted past it is dropped with
/// [`ErrorKind::DeadlineExceeded`] instead of burning a device slot.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunOptions {
    pub deadline: Option<Instant>,
}

impl RunOptions {
    /// Options with an absolute deadline `budget` from now.
    pub fn with_deadline_ms(deadline_ms: u64) -> RunOptions {
        RunOptions { deadline: Some(Instant::now() + Duration::from_millis(deadline_ms)) }
    }

    /// True once the deadline (if any) has passed.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// How the inference layer executes a servable against an input batch.
///
/// `predict`/`classify`/`regress`/`multi_inference` never call
/// `handle.run()` themselves; they go through a `Runner` so the
/// serving stack can substitute the cross-request batched path.
pub trait Runner: Send + Sync {
    /// Execute with per-request options (deadline propagation).
    fn run_opts(
        &self,
        handle: &ServableHandle<HloServable>,
        input: &Tensor,
        opts: &RunOptions,
    ) -> Result<Vec<OutTensor>>;

    /// Execute with default options (no deadline).
    fn run(
        &self,
        handle: &ServableHandle<HloServable>,
        input: &Tensor,
    ) -> Result<Vec<OutTensor>> {
        self.run_opts(handle, input, &RunOptions::default())
    }
}

/// Unbatched execution: dereference the handle and run. What library
/// callers get when they don't stand up a [`SessionRegistry`].
pub struct DirectRunner;

impl Runner for DirectRunner {
    fn run_opts(
        &self,
        handle: &ServableHandle<HloServable>,
        input: &Tensor,
        opts: &RunOptions,
    ) -> Result<Vec<OutTensor>> {
        // Expired-before-execution is still enforced on the direct
        // path: never start a device call whose client has given up.
        if opts.expired() {
            return Err(ErrorKind::DeadlineExceeded.err(format!(
                "deadline expired before execution of model '{}'",
                handle.id().name
            )));
        }
        handle.run(input)
    }
}

/// Per-model overrides for the batching knobs (unset fields inherit
/// the global [`BatchingConfig`] values).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BatchingOverride {
    pub max_batch_size: Option<usize>,
    pub batch_timeout: Option<Duration>,
    pub max_enqueued_batches: Option<usize>,
    /// Private device threads for this model's lanes (latency-critical
    /// models get a worker set no other model's backlog can occupy).
    /// Unset/None = the shared pool. Config parsing rejects 0.
    pub dedicated_threads: Option<usize>,
}

/// Cross-request batching knobs (`ServerConfig.batching`; the analogue
/// of TF-Serving's `BatchingParameters`).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchingConfig {
    /// Master switch: `false` restores direct per-request execution.
    pub enabled: bool,
    /// Shared device threads executing merged batches.
    pub num_batch_threads: usize,
    /// Maximum summed rows of one merged batch (clamped per servable
    /// to its compiled ladder's top).
    pub max_batch_size: usize,
    /// How long a non-full batch waits for batch-mates.
    pub batch_timeout: Duration,
    /// Closed-but-unprocessed batch limit before load shedding.
    pub max_enqueued_batches: usize,
    /// Lock shards for the global tensor buffer pools (0 = auto-size
    /// from the machine's parallelism; clamped via
    /// [`crate::util::pool::clamp_shards`]). Applied at server start,
    /// before the pools' first use.
    pub pool_shards: usize,
    /// Per-model overrides keyed by model name.
    pub per_model: HashMap<String, BatchingOverride>,
}

impl Default for BatchingConfig {
    fn default() -> Self {
        BatchingConfig {
            enabled: true,
            num_batch_threads: 2,
            max_batch_size: 16,
            batch_timeout: Duration::from_micros(2000),
            max_enqueued_batches: 64,
            pool_shards: 0,
            per_model: HashMap::new(),
        }
    }
}

impl BatchingConfig {
    /// Resolve the lane options for one model, applying its override.
    fn queue_options(&self, model: &str) -> QueueOptions {
        let o = self.per_model.get(model);
        QueueOptions {
            max_batch_size: o
                .and_then(|o| o.max_batch_size)
                .unwrap_or(self.max_batch_size),
            batch_timeout: o
                .and_then(|o| o.batch_timeout)
                .unwrap_or(self.batch_timeout),
            max_enqueued_batches: o
                .and_then(|o| o.max_enqueued_batches)
                .unwrap_or(self.max_enqueued_batches),
            dedicated_threads: o.and_then(|o| o.dedicated_threads).unwrap_or(0),
            ..Default::default()
        }
    }
}

/// The drain gate + device of one per-servable session: runs merged
/// batches against the retained servable handle until `closed`, then
/// refuses with a retryable error. Holding the handle here (not a weak
/// ref) is what makes "never a use-after-unload" structural: the
/// servable cannot be freed while this queue still owns work.
struct GatedRunner {
    closed: Arc<AtomicBool>,
    handle: ServableHandle<HloServable>,
}

impl BatchRunner for GatedRunner {
    fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
        if self.closed.load(Ordering::Acquire) {
            return Err(ErrorKind::FailedPrecondition.err(format!(
                "model '{}' version {} is unloading; request drained — retry",
                self.handle.id().name,
                self.handle.id().version
            )));
        }
        self.handle.run(&input)
    }
}

/// One live `(model, version)` batching session.
struct ServableSession {
    session: BatchingSession,
    closed: Arc<AtomicBool>,
}

impl ServableSession {
    fn run_with(&self, input: &Tensor, deadline: Option<Instant>) -> Result<Vec<OutTensor>> {
        if self.closed.load(Ordering::Acquire) {
            return Err(ErrorKind::FailedPrecondition
                .err("model version is unloading; retry"));
        }
        // Tensor is a view type: the clone is an O(1) Arc bump, and
        // the caller keeps ownership of the request storage (the
        // session's post-assembly recycle is declined while shared).
        self.session.run_with_deadline(input.clone(), deadline)
    }
}

/// One [`BatchingSession`] per loaded servable version, kept in sync
/// with the lifecycle via the manager's event bus. Implements
/// [`Runner`], so handing it to the inference layer puts every
/// Predict/Classify/Regress/MultiInference — from both wire planes —
/// through shared device batches.
pub struct SessionRegistry {
    scheduler: SharedBatchScheduler<PendingRun>,
    sessions: RwLock<HashMap<String, BTreeMap<u64, Arc<ServableSession>>>>,
    config: BatchingConfig,
    metrics: Arc<Registry>,
}

impl SessionRegistry {
    pub fn new(config: BatchingConfig, metrics: Arc<Registry>) -> Arc<SessionRegistry> {
        Arc::new(SessionRegistry {
            scheduler: SharedBatchScheduler::new(SchedulerOptions {
                num_batch_threads: config.num_batch_threads.max(1),
                name: "serving-batch".into(),
            }),
            sessions: RwLock::new(HashMap::new()),
            config,
            metrics,
        })
    }

    /// Wire this registry to a manager's lifecycle: sessions open when
    /// a version reaches `Ready` and drain when it starts unloading
    /// (or errors out). Already-ready versions get sessions
    /// immediately, so attach order doesn't matter.
    pub fn attach(self: &Arc<Self>, manager: &Arc<BasicManager>) {
        let registry = Arc::clone(self);
        // Weak: the manager owns the bus which owns this subscriber —
        // a strong ref back would leak the manager.
        let weak = Arc::downgrade(manager);
        manager.bus().subscribe(Arc::new(move |ev| {
            registry.observe(&weak, &ev.id, &ev.state);
        }));
        for id in manager.all_ready() {
            self.open_session(manager, &id);
        }
    }

    fn observe(&self, manager: &Weak<BasicManager>, id: &ServableId, state: &State) {
        match state {
            State::Ready => {
                if let Some(manager) = manager.upgrade() {
                    self.open_session(&manager, id);
                }
            }
            State::Unloading | State::Disabled | State::Error(_) => self.close_session(id),
            _ => {}
        }
    }

    /// Create (or replace) the session for `id`. Non-HLO servables
    /// (lookup tables) have no tensor batches to merge and are skipped.
    fn open_session(&self, manager: &Arc<BasicManager>, id: &ServableId) {
        if !self.config.enabled {
            return;
        }
        let Ok(handle) =
            manager.handle::<HloServable>(&id.name, VersionRequest::Specific(id.version))
        else {
            return;
        };
        let ladder = handle.allowed_batch_sizes();
        let mut queue = self.config.queue_options(&id.name);
        // A merged batch must stay paddable: clamp to the ladder top.
        if let Some(&top) = ladder.last() {
            queue.max_batch_size = queue.max_batch_size.min(top);
        }
        // Never hand the scheduler a zero-capacity queue (its
        // `add_queue` asserts) — config parsing rejects 0, but this
        // layer guards for programmatic configs too.
        queue.max_batch_size = queue.max_batch_size.max(1);
        // Lane identity: the per-model depth gauge (versions of one
        // model share it; adds and drains net out correctly).
        queue.depth_gauge = Some(
            self.metrics
                .gauge(&format!("batch.{}.lane_depth", id.name)),
        );
        let closed = Arc::new(AtomicBool::new(false));
        let runner = GatedRunner { closed: Arc::clone(&closed), handle };
        let options = SessionOptions {
            queue,
            allowed_batch_sizes: ladder,
            queue_delay_ns: Some(
                self.metrics
                    .histogram(&format!("batch.{}.queue_delay_ns", id.name)),
            ),
            // Windowed sibling: what the fleet Synchronizer scrapes so
            // SLO-breach autoscaling reacts to *recent* queue pressure.
            queue_delay_window: Some(
                self.metrics
                    .windowed_histogram(&format!("batch.{}.queue_delay_ns.window", id.name)),
            ),
            merged_batch_rows: Some(
                self.metrics
                    .histogram(&format!("batch.{}.merged_rows", id.name)),
            ),
        };
        let session = BatchingSession::new(
            &self.scheduler,
            &format!("{}:{}", id.name, id.version),
            options,
            Arc::new(runner),
        );
        let fresh = Arc::new(ServableSession { session, closed });
        {
            // First-wins: attach's initial scan and the Ready event can
            // both try to open the same version; the loser discards its
            // session so requests already queued on the winner are
            // never spuriously drained. (A version can only re-load
            // after Disabled, which removed the old entry.)
            let mut sessions = self.sessions.write().unwrap();
            let versions = sessions.entry(id.name.clone()).or_default();
            if versions.contains_key(&id.version) {
                drop(sessions);
                fresh.closed.store(true, Ordering::Release);
                fresh.session.close();
                return;
            }
            versions.insert(id.version, fresh);
        }
        self.metrics.gauge("batch.sessions").add(1);
        // Unload race: `Unloading` publishes before the serving-map
        // removal, so a concurrent unload's close event may fire before
        // our insert. Re-check and self-close if the version already
        // left the map; the Disabled-event close (published after
        // removal) is the backstop for the narrower window where this
        // re-check still sees the version serving.
        if !manager.ready_versions(&id.name).contains(&id.version) {
            self.close_session(id);
            return;
        }
        crate::log_info!("batching session open for {id}");
    }

    /// Drain the session for `id`: gate future batches, then drop the
    /// queue handle so already-queued work flushes (each queued caller
    /// is answered with FailedPrecondition by the gate).
    fn close_session(&self, id: &ServableId) {
        let removed = {
            let mut sessions = self.sessions.write().unwrap();
            let Some(versions) = sessions.get_mut(&id.name) else { return };
            let removed = versions.remove(&id.version);
            if versions.is_empty() {
                sessions.remove(&id.name);
            }
            removed
        };
        if let Some(session) = removed {
            // Order matters: gate the runner first, then close the
            // queue so the eager flush finds the gate down — queued
            // callers are answered (with FailedPrecondition) right
            // away instead of waiting out a batch timeout, even while
            // in-flight request threads still hold session refs.
            session.closed.store(true, Ordering::Release);
            session.session.close();
            self.metrics.gauge("batch.sessions").add(-1);
            crate::log_info!("batching session drained for {id}");
        }
    }

    /// Number of live sessions (tests/diagnostics).
    pub fn session_count(&self) -> usize {
        self.sessions.read().unwrap().values().map(BTreeMap::len).sum()
    }

    /// Tasks queued (not yet executed) in one version's session; 0 if
    /// no session exists. Tests use this to arrange unload-while-
    /// queued deterministically.
    pub fn pending_tasks(&self, id: &ServableId) -> usize {
        self.session_for(id).map_or(0, |s| s.session.pending_tasks())
    }

    fn session_for(&self, id: &ServableId) -> Option<Arc<ServableSession>> {
        self.sessions
            .read()
            .unwrap()
            .get(&id.name)
            .and_then(|versions| versions.get(&id.version))
            .cloned()
    }
}

impl Runner for SessionRegistry {
    fn run_opts(
        &self,
        handle: &ServableHandle<HloServable>,
        input: &Tensor,
        opts: &RunOptions,
    ) -> Result<Vec<OutTensor>> {
        if !self.config.enabled {
            return DirectRunner.run_opts(handle, input, opts);
        }
        match self.session_for(handle.id()) {
            Some(session) => session.run_with(input, opts.deadline),
            // No session (registry not attached to this version's
            // lifecycle, or the servable was loaded out of band):
            // direct execution, never an error.
            None => DirectRunner.run_opts(handle, input, opts),
        }
    }
}

/// Admission-control knobs (`ServerConfig.admission`). Both caps
/// default to 0 = unlimited, so admission is strictly opt-in.
#[derive(Debug, Clone, PartialEq)]
pub struct AdmissionConfig {
    /// Max concurrently-admitted data-plane requests across all models
    /// (0 = unlimited). Excess load is shed with a retryable
    /// [`ErrorKind::Unavailable`] instead of queueing without bound.
    pub max_inflight: usize,
    /// Max concurrently-admitted requests per model (0 = unlimited).
    pub max_inflight_per_model: usize,
    /// Backoff hint returned to shed clients (the HTTP gateway's
    /// `Retry-After` header, rounded up to whole seconds).
    pub retry_after_ms: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_inflight: 0, max_inflight_per_model: 0, retry_after_ms: 50 }
    }
}

/// Bounded-in-flight admission control and the drain switch (§"graceful
/// degradation"). Every data-plane request acquires a [`Permit`] before
/// touching the serving map; the permit's `Drop` releases the slots, so
/// early returns and panics can't leak capacity. When the server is
/// draining (shutdown in progress) all new work is refused retryably
/// while already-admitted requests finish.
pub struct AdmissionControl {
    config: AdmissionConfig,
    inflight: AtomicUsize,
    per_model: Mutex<HashMap<String, Arc<AtomicUsize>>>,
    draining: AtomicBool,
    shed: Arc<crate::util::metrics::Counter>,
}

impl AdmissionControl {
    pub fn new(config: AdmissionConfig, metrics: &Registry) -> Arc<AdmissionControl> {
        Arc::new(AdmissionControl {
            config,
            inflight: AtomicUsize::new(0),
            per_model: Mutex::new(HashMap::new()),
            draining: AtomicBool::new(false),
            shed: metrics.counter("admission.shed"),
        })
    }

    /// Try to admit one request against `model`. On success the
    /// returned permit holds the slots until dropped; on refusal the
    /// error is [`ErrorKind::Unavailable`] (retryable).
    pub fn admit(self: &Arc<Self>, model: &str) -> Result<Permit> {
        if self.draining.load(Ordering::Acquire) {
            self.shed.inc();
            return Err(ErrorKind::Unavailable
                .err("server is draining; retry against another replica"));
        }
        if !try_acquire(&self.inflight, self.config.max_inflight) {
            self.shed.inc();
            return Err(ErrorKind::Unavailable.err(format!(
                "overloaded: server at its global in-flight cap ({})",
                self.config.max_inflight
            )));
        }
        let lane = Arc::clone(
            self.per_model
                .lock()
                .unwrap()
                .entry(model.to_string())
                .or_default(),
        );
        if !try_acquire(&lane, self.config.max_inflight_per_model) {
            self.inflight.fetch_sub(1, Ordering::AcqRel);
            self.shed.inc();
            return Err(ErrorKind::Unavailable.err(format!(
                "overloaded: model '{model}' at its in-flight cap ({})",
                self.config.max_inflight_per_model
            )));
        }
        Ok(Permit { control: Arc::clone(self), lane })
    }

    /// Flip the drain switch: every subsequent `admit` refuses
    /// retryably. Idempotent.
    pub fn start_draining(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Currently-admitted requests (all models).
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    /// Backoff hint for shed clients, rounded up to whole seconds
    /// (HTTP `Retry-After` has one-second resolution).
    pub fn retry_after_secs(&self) -> u64 {
        self.config.retry_after_ms.div_ceil(1000).max(1)
    }

    /// Block until every admitted request has finished, or `timeout`
    /// elapses. Returns `true` if fully drained.
    pub fn wait_idle(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        while self.inflight() > 0 {
            if start.elapsed() >= timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }
}

/// Increment `counter` unless it already sits at `cap` (0 = no cap —
/// still counted, so drain can watch in-flight reach zero).
fn try_acquire(counter: &AtomicUsize, cap: usize) -> bool {
    if cap == 0 {
        counter.fetch_add(1, Ordering::AcqRel);
        return true;
    }
    let mut cur = counter.load(Ordering::Acquire);
    loop {
        if cur >= cap {
            return false;
        }
        match counter.compare_exchange_weak(cur, cur + 1, Ordering::AcqRel, Ordering::Acquire) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// RAII admission slot: releases the global and per-model counters on
/// drop, whatever path the request exits by.
pub struct Permit {
    control: Arc<AdmissionControl>,
    lane: Arc<AtomicUsize>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.lane.fetch_sub(1, Ordering::AcqRel);
        self.control.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::ArtifactSpec;
    use crate::runtime::hlo_servable::synthetic_loader;

    fn manager_with(versions: &[u64]) -> Arc<BasicManager> {
        let m = BasicManager::with_defaults();
        for &v in versions {
            m.load_and_wait(
                ServableId::new("m", v),
                synthetic_loader(ArtifactSpec::synthetic_classifier("m", v, 4, 2)),
                Duration::from_secs(10),
            )
            .unwrap();
        }
        m
    }

    fn registry(config: BatchingConfig) -> Arc<SessionRegistry> {
        SessionRegistry::new(config, Registry::new())
    }

    #[test]
    fn sessions_track_the_lifecycle() {
        let m = manager_with(&[1]);
        let r = registry(BatchingConfig::default());
        r.attach(&m);
        // Pre-attach versions got a session; new loads add one; unloads
        // remove theirs.
        assert_eq!(r.session_count(), 1);
        m.load_and_wait(
            ServableId::new("m", 2),
            synthetic_loader(ArtifactSpec::synthetic_classifier("m", 2, 4, 2)),
            Duration::from_secs(10),
        )
        .unwrap();
        assert_eq!(r.session_count(), 2);
        m.unload_and_wait(ServableId::new("m", 1), Duration::from_secs(10))
            .unwrap();
        assert_eq!(r.session_count(), 1);
        // Results through the registry match direct execution.
        let handle = m
            .handle::<HloServable>("m", VersionRequest::Latest)
            .unwrap();
        let input = Tensor::matrix(vec![vec![0.5, 1.0, -1.0, 0.25]]).unwrap();
        let batched = r.run(&handle, &input).unwrap();
        let direct = handle.run(&input).unwrap();
        assert_eq!(batched, direct);
    }

    #[test]
    fn disabled_config_runs_direct() {
        let m = manager_with(&[1]);
        let r = registry(BatchingConfig { enabled: false, ..Default::default() });
        r.attach(&m);
        assert_eq!(r.session_count(), 0);
        let handle = m.handle::<HloServable>("m", VersionRequest::Latest).unwrap();
        let input = Tensor::zeros(vec![1, 4]);
        assert_eq!(r.run(&handle, &input).unwrap().len(), 2);
    }

    #[test]
    fn unattached_servables_fall_back_to_direct() {
        let m = manager_with(&[1]);
        let r = registry(BatchingConfig::default());
        // Never attached: no sessions, but runs still succeed.
        assert_eq!(r.session_count(), 0);
        let handle = m.handle::<HloServable>("m", VersionRequest::Latest).unwrap();
        assert_eq!(r.run(&handle, &Tensor::zeros(vec![1, 4])).unwrap().len(), 2);
    }

    #[test]
    fn per_model_overrides_resolve() {
        let mut config = BatchingConfig::default();
        config.per_model.insert(
            "special".into(),
            BatchingOverride {
                max_batch_size: Some(64),
                batch_timeout: Some(Duration::from_micros(500)),
                max_enqueued_batches: None,
                dedicated_threads: Some(2),
            },
        );
        let q = config.queue_options("special");
        assert_eq!(q.max_batch_size, 64);
        assert_eq!(q.batch_timeout, Duration::from_micros(500));
        assert_eq!(q.max_enqueued_batches, config.max_enqueued_batches);
        assert_eq!(q.dedicated_threads, 2);
        let q = config.queue_options("other");
        assert_eq!(q.max_batch_size, config.max_batch_size);
        assert_eq!(q.dedicated_threads, 0, "no override: shared pool");
    }

    #[test]
    fn lane_depth_gauge_registers_per_model() {
        let m = manager_with(&[1]);
        let metrics = Registry::new();
        let r = SessionRegistry::new(BatchingConfig::default(), Arc::clone(&metrics));
        r.attach(&m);
        // The lane gauge exists once a session opens, and drains to 0
        // after a request completes.
        let handle = m.handle::<HloServable>("m", VersionRequest::Latest).unwrap();
        let input = Tensor::matrix(vec![vec![0.5, 1.0, -1.0, 0.25]]).unwrap();
        r.run(&handle, &input).unwrap();
        assert_eq!(metrics.gauge("batch.m.lane_depth").get(), 0);
    }

    #[test]
    fn concurrent_runs_merge_into_fewer_executions() {
        let m = manager_with(&[1]);
        let r = registry(BatchingConfig {
            batch_timeout: Duration::from_millis(20),
            ..Default::default()
        });
        r.attach(&m);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let m = Arc::clone(&m);
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let handle =
                        m.handle::<HloServable>("m", VersionRequest::Latest).unwrap();
                    let row: Vec<f32> = (0..4).map(|j| (i * 4 + j) as f32 * 0.1).collect();
                    r.run(&handle, &Tensor::matrix(vec![row]).unwrap()).unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap().len(), 2);
        }
        let servable = m.handle::<HloServable>("m", VersionRequest::Latest).unwrap();
        assert!(
            servable.executions() < 8,
            "8 concurrent requests never merged: {} executions",
            servable.executions()
        );
    }

    #[test]
    fn expired_deadline_never_reaches_the_device() {
        let m = manager_with(&[1]);
        let handle = m.handle::<HloServable>("m", VersionRequest::Latest).unwrap();
        let input = Tensor::zeros(vec![1, 4]);
        let before = handle.executions();
        // An already-expired deadline is refused on the direct path...
        let expired = RunOptions { deadline: Some(Instant::now() - Duration::from_millis(5)) };
        let e = DirectRunner.run_opts(&handle, &input, &expired).unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::DeadlineExceeded);
        // ...and on the registry's fallback path — without executing.
        let r = registry(BatchingConfig::default());
        let e = r.run_opts(&handle, &input, &expired).unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::DeadlineExceeded);
        assert_eq!(handle.executions(), before, "expired work must not execute");
        // A generous deadline sails through.
        let ok = RunOptions::with_deadline_ms(10_000);
        assert_eq!(r.run_opts(&handle, &input, &ok).unwrap().len(), 2);
    }

    #[test]
    fn admission_caps_and_permit_release() {
        let metrics = Registry::new();
        let ac = AdmissionControl::new(
            AdmissionConfig { max_inflight: 2, max_inflight_per_model: 1, retry_after_ms: 1500 },
            &metrics,
        );
        let a = ac.admit("x").unwrap();
        // Per-model cap refuses a second 'x' while 'y' still fits.
        let e = ac.admit("x").unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::Unavailable);
        let b = ac.admit("y").unwrap();
        // Global cap (2) now refuses even a fresh model.
        let e = ac.admit("z").unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::Unavailable);
        assert_eq!(ac.inflight(), 2);
        assert_eq!(metrics.counter("admission.shed").get(), 2);
        // Dropping permits frees both the lane and the global slot.
        drop(a);
        assert_eq!(ac.inflight(), 1);
        ac.admit("x").unwrap();
        drop(b);
        assert_eq!(ac.retry_after_secs(), 2, "1500ms rounds up to 2s");
    }

    #[test]
    fn draining_refuses_new_work_and_waits_for_stragglers() {
        let metrics = Registry::new();
        let ac = AdmissionControl::new(AdmissionConfig::default(), &metrics);
        let straggler = ac.admit("m").unwrap();
        ac.start_draining();
        assert!(ac.is_draining());
        let e = ac.admit("m").unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::Unavailable);
        // Unlimited caps still count in-flight, so drain can observe it.
        assert!(!ac.wait_idle(Duration::from_millis(5)), "straggler still running");
        drop(straggler);
        assert!(ac.wait_idle(Duration::from_secs(1)));
    }
}
