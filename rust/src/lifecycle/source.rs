//! Sources: discover servable versions in external storage (§2.1).
//!
//! * [`FileSystemSource`] — the canonical Source: polls a base directory
//!   per servable for numeric version subdirectories and aspires
//!   according to a per-servable [`ServingPolicy`] (latest-N / specific
//!   versions / all), which is how §2.1.1 canary ("aspire the two
//!   newest") and rollback ("aspire a specific older version") are
//!   expressed.
//! * [`StaticSource`] — emits a fixed set once (tests, embedded use).
//! * The TFS² RPC-driven source lives in [`crate::tfs2::synchronizer`].

use crate::base::aspired::{AspiredVersionsCallback, ServableData, Source};
use crate::base::servable::ServableId;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which versions in a directory a servable should aspire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServingPolicy {
    /// Serve the N largest version numbers. `Latest(1)` is the default
    /// casual deployment; `Latest(2)` is the §2.1.1 canary setup.
    Latest(usize),
    /// Serve exactly these versions (rollback pins an older one).
    Specific(Vec<u64>),
    /// Serve every version present.
    All,
}

impl ServingPolicy {
    /// Apply to the set of versions found on storage (ascending).
    pub fn select(&self, available: &[u64]) -> Vec<u64> {
        match self {
            ServingPolicy::Latest(n) => {
                let mut v: Vec<u64> =
                    available.iter().rev().take(*n).copied().collect();
                v.sort_unstable();
                v
            }
            ServingPolicy::Specific(wanted) => {
                let set: BTreeSet<u64> = available.iter().copied().collect();
                let mut v: Vec<u64> =
                    wanted.iter().filter(|w| set.contains(w)).copied().collect();
                v.sort_unstable();
                v.dedup();
                v
            }
            ServingPolicy::All => available.to_vec(),
        }
    }
}

/// One servable watched by the file-system source.
#[derive(Debug, Clone)]
pub struct WatchedServable {
    pub name: String,
    pub base_path: PathBuf,
    pub policy: ServingPolicy,
}

/// Scan `base_path` for numeric version subdirectories (ascending).
pub fn scan_versions(base_path: &Path) -> Vec<u64> {
    let mut versions: Vec<u64> = match std::fs::read_dir(base_path) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter(|e| e.path().is_dir())
            .filter_map(|e| e.file_name().to_string_lossy().parse::<u64>().ok())
            .collect(),
        Err(_) => Vec::new(), // not-yet-created base path = no versions
    };
    versions.sort_unstable();
    versions
}

/// Polls the file system and emits aspired versions (payload = version
/// directory path).
pub struct FileSystemSource {
    watched: Mutex<Vec<WatchedServable>>,
    callback: Mutex<Option<Arc<dyn AspiredVersionsCallback<PathBuf>>>>,
    poll_interval: Option<Duration>,
    stop: Arc<AtomicBool>,
    poller: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl FileSystemSource {
    /// `poll_interval = None`: manual polling only ([`Self::poll_once`]).
    pub fn new(watched: Vec<WatchedServable>, poll_interval: Option<Duration>) -> Arc<Self> {
        Arc::new(FileSystemSource {
            watched: Mutex::new(watched),
            callback: Mutex::new(None),
            poll_interval,
            stop: Arc::new(AtomicBool::new(false)),
            poller: Mutex::new(None),
        })
    }

    /// Replace the policy for one servable (canary/rollback controls).
    pub fn set_policy(&self, name: &str, policy: ServingPolicy) {
        let mut w = self.watched.lock().unwrap();
        if let Some(s) = w.iter_mut().find(|s| s.name == name) {
            s.policy = policy;
        }
    }

    /// Add a servable to watch.
    pub fn watch(&self, servable: WatchedServable) {
        self.watched.lock().unwrap().push(servable);
    }

    /// Is `name` already watched?
    pub fn is_watching(&self, name: &str) -> bool {
        self.watched.lock().unwrap().iter().any(|s| s.name == name)
    }

    /// One synchronous poll: scan + emit full aspired state (idempotent
    /// — §2.1: the source "emits … without needing to know which ones
    /// currently are in memory").
    pub fn poll_once(&self) {
        let cb = match self.callback.lock().unwrap().clone() {
            Some(cb) => cb,
            None => return,
        };
        let watched = self.watched.lock().unwrap().clone();
        for s in watched {
            let available = scan_versions(&s.base_path);
            let aspired = s.policy.select(&available);
            let data: Vec<ServableData<PathBuf>> = aspired
                .into_iter()
                .map(|v| {
                    ServableData::ok(
                        ServableId::new(s.name.clone(), v),
                        s.base_path.join(v.to_string()),
                    )
                })
                .collect();
            cb.set_aspired_versions(&s.name, data);
        }
    }

    fn start_polling(self: &Arc<Self>) {
        let interval = match self.poll_interval {
            Some(i) => i,
            None => return,
        };
        let weak = Arc::downgrade(self);
        let stop = Arc::clone(&self.stop);
        let handle = std::thread::Builder::new()
            .name("fs-source-poll".to_string())
            .spawn(move || loop {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                match weak.upgrade() {
                    Some(src) => src.poll_once(),
                    None => return,
                }
                std::thread::sleep(interval);
            })
            .expect("spawn source poller");
        *self.poller.lock().unwrap() = Some(handle);
    }
}

impl Source<PathBuf> for Arc<FileSystemSource> {
    fn set_aspired_versions_callback(
        &mut self,
        cb: Arc<dyn AspiredVersionsCallback<PathBuf>>,
    ) {
        *self.callback.lock().unwrap() = Some(cb);
        self.poll_once(); // emit initial state immediately
        self.start_polling();
    }
}

impl Drop for FileSystemSource {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.poller.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// Emits a fixed aspired set on connect (and on demand).
pub struct StaticSource<T: Clone + Send + 'static> {
    items: Vec<(String, Vec<(u64, T)>)>,
    callback: Option<Arc<dyn AspiredVersionsCallback<T>>>,
}

impl<T: Clone + Send + 'static> StaticSource<T> {
    pub fn new(items: Vec<(String, Vec<(u64, T)>)>) -> Self {
        StaticSource { items, callback: None }
    }

    pub fn emit(&self) {
        if let Some(cb) = &self.callback {
            for (name, versions) in &self.items {
                let data = versions
                    .iter()
                    .map(|(v, payload)| {
                        ServableData::ok(ServableId::new(name.clone(), *v), payload.clone())
                    })
                    .collect();
                cb.set_aspired_versions(name, data);
            }
        }
    }
}

impl<T: Clone + Send + Sync + 'static> Source<T> for StaticSource<T> {
    fn set_aspired_versions_callback(&mut self, cb: Arc<dyn AspiredVersionsCallback<T>>) {
        self.callback = Some(cb);
        self.emit();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::aspired::RecordingCallback;

    fn make_version_dirs(root: &Path, name: &str, versions: &[u64]) -> PathBuf {
        let base = root.join(name);
        for v in versions {
            std::fs::create_dir_all(base.join(v.to_string())).unwrap();
        }
        base
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "tensorserve-test-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn policy_selection() {
        let avail = vec![1, 2, 5, 9];
        assert_eq!(ServingPolicy::Latest(1).select(&avail), vec![9]);
        assert_eq!(ServingPolicy::Latest(2).select(&avail), vec![5, 9]);
        assert_eq!(ServingPolicy::Latest(10).select(&avail), vec![1, 2, 5, 9]);
        assert_eq!(
            ServingPolicy::Specific(vec![2, 7, 5]).select(&avail),
            vec![2, 5]
        );
        assert_eq!(ServingPolicy::All.select(&avail), avail);
        assert_eq!(ServingPolicy::Latest(1).select(&[]), Vec::<u64>::new());
    }

    #[test]
    fn scan_versions_numeric_dirs_only() {
        let root = tmpdir("scan");
        let base = make_version_dirs(&root, "m", &[3, 1, 12]);
        std::fs::create_dir_all(base.join("not-a-version")).unwrap();
        std::fs::write(base.join("7"), b"file not dir").unwrap();
        assert_eq!(scan_versions(&base), vec![1, 3, 12]);
        assert_eq!(scan_versions(&root.join("missing")), Vec::<u64>::new());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn fs_source_emits_on_connect_and_poll() {
        let root = tmpdir("emit");
        let base = make_version_dirs(&root, "m", &[1, 2]);
        let mut src = FileSystemSource::new(
            vec![WatchedServable {
                name: "m".into(),
                base_path: base.clone(),
                policy: ServingPolicy::Latest(1),
            }],
            None,
        );
        let cb = RecordingCallback::<PathBuf>::new();
        src.set_aspired_versions_callback(cb.clone());
        assert_eq!(cb.latest_for("m"), Some(vec![2]));

        // New version appears on storage.
        std::fs::create_dir_all(base.join("3")).unwrap();
        src.poll_once();
        assert_eq!(cb.latest_for("m"), Some(vec![3]));
        // Payload is the version directory.
        let calls = cb.calls.lock().unwrap();
        let last = calls.last().unwrap();
        assert_eq!(last.1[0].payload.as_ref().unwrap(), &base.join("3"));
        drop(calls);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn fs_source_canary_policy_switch() {
        let root = tmpdir("canary");
        let base = make_version_dirs(&root, "m", &[1, 2]);
        let mut src = FileSystemSource::new(
            vec![WatchedServable {
                name: "m".into(),
                base_path: base,
                policy: ServingPolicy::Latest(1),
            }],
            None,
        );
        let cb = RecordingCallback::<PathBuf>::new();
        src.set_aspired_versions_callback(cb.clone());
        assert_eq!(cb.latest_for("m"), Some(vec![2]));
        // Canary: both newest versions.
        src.set_policy("m", ServingPolicy::Latest(2));
        src.poll_once();
        assert_eq!(cb.latest_for("m"), Some(vec![1, 2]));
        // Rollback: pin version 1.
        src.set_policy("m", ServingPolicy::Specific(vec![1]));
        src.poll_once();
        assert_eq!(cb.latest_for("m"), Some(vec![1]));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn fs_source_background_polling() {
        let root = tmpdir("poll");
        let base = make_version_dirs(&root, "m", &[1]);
        let mut src = FileSystemSource::new(
            vec![WatchedServable {
                name: "m".into(),
                base_path: base.clone(),
                policy: ServingPolicy::Latest(1),
            }],
            Some(Duration::from_millis(5)),
        );
        let cb = RecordingCallback::<PathBuf>::new();
        src.set_aspired_versions_callback(cb.clone());
        std::fs::create_dir_all(base.join("2")).unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while std::time::Instant::now() < deadline {
            if cb.latest_for("m") == Some(vec![2]) {
                let _ = std::fs::remove_dir_all(&root);
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("poller never discovered version 2");
    }

    #[test]
    fn static_source_emits_fixed_set() {
        let mut src =
            StaticSource::new(vec![("m".into(), vec![(1, "a"), (2, "b")])]);
        let cb = RecordingCallback::<&str>::new();
        src.set_aspired_versions_callback(cb.clone());
        assert_eq!(cb.latest_for("m"), Some(vec![1, 2]));
    }
}
