//! Model lifecycle management (paper §2.1, Figure 1).
//!
//! The chain: [`source::FileSystemSource`] (or [`source::StaticSource`] /
//! an RPC-driven source in TFS²) discovers versions →
//! [`source_router::SourceRouter`] splits streams by platform →
//! [`source_adapter`]s turn storage paths into `Loader`s →
//! [`manager::AspiredVersionsManager`] sequences loads/unloads under a
//! [`policy`] and serves reference-counted handles out of an RCU map
//! ([`basic_manager::BasicManager`]).

pub mod basic_manager;
pub mod harness;
pub mod labels;
pub mod manager;
pub mod monitor;
pub mod policy;
pub mod source;
pub mod source_adapter;
pub mod source_router;
