//! [`LabelResolver`]: version labels ("canary", "stable", …) over
//! serving versions — how TFS² does safe rollouts (§2.1.1 / Olston et
//! al. 2017). A label is an indirection clients address instead of a
//! numeric version; flipping `canary → v7` is one admin RPC, no client
//! redeploy.
//!
//! Invariants:
//! * a label may only be attached to a version that is **loaded and
//!   serving** at set time (callers pass the current ready set), so a
//!   labeled lookup never lands on an unloaded version at flip time;
//! * relabeling while serving is allowed and atomic (readers see the
//!   old or the new version, never nothing);
//! * resolution is a read-lock map lookup, consulted only for labeled
//!   requests — unlabeled lookups never touch it.
//!
//! The serving guarantee is **set-time only** (checked against a
//! snapshot of the ready set): if the labeled version later unloads,
//! labeled lookups fail loudly ("no version N") until an operator
//! re-issues `SetVersionLabel` — the resolver does not track the
//! lifecycle. Automatic invalidation/remap on unload (and label
//! persistence in the TFS² store) is a ROADMAP follow-on.

use anyhow::{bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::RwLock;

/// model → (label → version).
#[derive(Default)]
pub struct LabelResolver {
    map: RwLock<HashMap<String, BTreeMap<String, u64>>>,
}

impl LabelResolver {
    pub fn new() -> LabelResolver {
        LabelResolver::default()
    }

    /// Attach (or move) `label` on `model` to `version`. `serving` is
    /// the caller's current ready-version set; labeling anything
    /// outside it is rejected so labels always point at servable
    /// versions.
    pub fn set(&self, model: &str, label: &str, version: u64, serving: &[u64]) -> Result<()> {
        if label.is_empty() {
            bail!("model '{model}': empty version label");
        }
        if !serving.contains(&version) {
            bail!(
                "cannot label {model}:{version} as '{label}': version is not loaded and \
                 serving (serving versions: {serving:?})"
            );
        }
        self.map
            .write()
            .unwrap()
            .entry(model.to_string())
            .or_default()
            .insert(label.to_string(), version);
        Ok(())
    }

    /// Resolve `label` on `model` to its pinned version.
    pub fn resolve(&self, model: &str, label: &str) -> Result<u64> {
        let map = self.map.read().unwrap();
        match map.get(model).and_then(|labels| labels.get(label)) {
            Some(&v) => Ok(v),
            None => {
                let known: Vec<String> = map
                    .get(model)
                    .map(|l| l.keys().cloned().collect())
                    .unwrap_or_default();
                bail!(
                    "model '{model}' has no version labeled '{label}' (known labels: {known:?})"
                )
            }
        }
    }

    /// Remove one label. Returns whether it existed.
    pub fn remove(&self, model: &str, label: &str) -> bool {
        self.map
            .write()
            .unwrap()
            .get_mut(model)
            .map(|labels| labels.remove(label).is_some())
            .unwrap_or(false)
    }

    /// All `(label, version)` pairs for a model, sorted by label.
    pub fn labels(&self, model: &str) -> Vec<(String, u64)> {
        self.map
            .read()
            .unwrap()
            .get(model)
            .map(|l| l.iter().map(|(k, &v)| (k.clone(), v)).collect())
            .unwrap_or_default()
    }

    /// Labels currently attached to one specific version of a model.
    pub fn labels_of_version(&self, model: &str, version: u64) -> Vec<String> {
        self.map
            .read()
            .unwrap()
            .get(model)
            .map(|l| {
                l.iter()
                    .filter(|(_, &v)| v == version)
                    .map(|(k, _)| k.clone())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_resolve_roundtrip() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1, 2]).unwrap();
        r.set("m", "canary", 2, &[1, 2]).unwrap();
        assert_eq!(r.resolve("m", "stable").unwrap(), 1);
        assert_eq!(r.resolve("m", "canary").unwrap(), 2);
        assert_eq!(
            r.labels("m"),
            vec![("canary".to_string(), 2), ("stable".to_string(), 1)]
        );
        assert_eq!(r.labels_of_version("m", 2), vec!["canary".to_string()]);
    }

    #[test]
    fn unknown_label_errors_and_lists_known() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1]).unwrap();
        let err = r.resolve("m", "canary").unwrap_err().to_string();
        assert!(err.contains("canary") && err.contains("stable"), "{err}");
        // Unknown model too.
        let err = r.resolve("ghost", "stable").unwrap_err().to_string();
        assert!(err.contains("ghost"), "{err}");
    }

    #[test]
    fn labeling_unserved_version_rejected() {
        let r = LabelResolver::new();
        let err = r.set("m", "canary", 9, &[1, 2]).unwrap_err().to_string();
        assert!(err.contains("not loaded and serving"), "{err}");
        assert!(r.resolve("m", "canary").is_err());
        // Empty label rejected too.
        assert!(r.set("m", "", 1, &[1]).is_err());
    }

    #[test]
    fn relabel_during_serving_moves_the_pointer() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1, 2]).unwrap();
        assert_eq!(r.resolve("m", "stable").unwrap(), 1);
        // Promote: stable now points at v2.
        r.set("m", "stable", 2, &[1, 2]).unwrap();
        assert_eq!(r.resolve("m", "stable").unwrap(), 2);
        assert_eq!(r.labels("m").len(), 1);
    }

    #[test]
    fn remove_label() {
        let r = LabelResolver::new();
        r.set("m", "canary", 1, &[1]).unwrap();
        assert!(r.remove("m", "canary"));
        assert!(!r.remove("m", "canary"));
        assert!(r.resolve("m", "canary").is_err());
    }

    #[test]
    fn models_are_independent() {
        let r = LabelResolver::new();
        r.set("a", "stable", 1, &[1]).unwrap();
        r.set("b", "stable", 2, &[2]).unwrap();
        assert_eq!(r.resolve("a", "stable").unwrap(), 1);
        assert_eq!(r.resolve("b", "stable").unwrap(), 2);
    }
}
