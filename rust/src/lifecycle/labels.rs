//! [`LabelResolver`]: version labels ("canary", "stable", …) over
//! serving versions — how TFS² does safe rollouts (§2.1.1 / Olston et
//! al. 2017). A label is an indirection clients address instead of a
//! numeric version; flipping `canary → v7` is one admin RPC, no client
//! redeploy.
//!
//! Invariants:
//! * a label may only be attached to a version that is **loaded and
//!   serving** at set time (callers pass the current ready set), so a
//!   labeled lookup never lands on an unloaded version at flip time;
//! * relabeling while serving is allowed and atomic (readers see the
//!   old or the new version, never nothing);
//! * resolution is a read-lock map lookup, consulted only for labeled
//!   requests — unlabeled lookups never touch it.
//!
//! The resolver itself does not watch the lifecycle; the server keeps
//! labels consistent with it from the outside:
//! * the unload path calls [`LabelResolver::remove_version`] (an
//!   event-bus subscription in `server::builder`), so labels never
//!   dangle on an unloaded version — a labeled lookup afterwards
//!   reports "no version labeled …";
//! * `SetVersionLabel` re-checks the ready set after the insert and
//!   uses [`LabelResolver::rollback`] (compare-and-rollback) if the
//!   version unloaded concurrently, restoring the prior mapping when
//!   it still serves.
//!
//! Persistence: this resolver is in-memory only. When the server is
//! configured with `label_store_path`, `server::builder` writes every
//! label mutation through the transactional `tfs2::store` and replays
//! the persisted mappings on Ready events, so canary/stable labels
//! survive restarts; the TFS² Controller keeps its own authoritative
//! copy under `label/{model}/{label}` in the control-plane store.

use crate::bail_kind;
use crate::base::error::ErrorKind;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::RwLock;

/// model → (label → version).
#[derive(Default)]
pub struct LabelResolver {
    map: RwLock<HashMap<String, BTreeMap<String, u64>>>,
}

impl LabelResolver {
    pub fn new() -> LabelResolver {
        LabelResolver::default()
    }

    /// Attach (or move) `label` on `model` to `version`. `serving` is
    /// the caller's current ready-version set; labeling anything
    /// outside it is rejected so labels always point at servable
    /// versions. Returns the version the label previously pointed at
    /// (so callers racing an unload can [`LabelResolver::rollback`]).
    pub fn set(
        &self,
        model: &str,
        label: &str,
        version: u64,
        serving: &[u64],
    ) -> Result<Option<u64>> {
        if label.is_empty() {
            bail_kind!(ErrorKind::InvalidArgument, "model '{model}': empty version label");
        }
        if !serving.contains(&version) {
            bail_kind!(
                ErrorKind::FailedPrecondition,
                "cannot label {model}:{version} as '{label}': version is not loaded and \
                 serving (serving versions: {serving:?})"
            );
        }
        Ok(self
            .map
            .write()
            .unwrap()
            .entry(model.to_string())
            .or_default()
            .insert(label.to_string(), version))
    }

    /// Compare-and-rollback for the set-time unload race: if `label`
    /// still points at `version`, restore it to `prev` (or drop it
    /// when `prev` is `None`). A no-op when a concurrent admin already
    /// moved the label — their acknowledged mapping is never
    /// clobbered. Returns whether anything changed.
    pub fn rollback(&self, model: &str, label: &str, version: u64, prev: Option<u64>) -> bool {
        let mut map = self.map.write().unwrap();
        let Some(labels) = map.get_mut(model) else {
            return false;
        };
        if labels.get(label) != Some(&version) {
            return false;
        }
        match prev {
            Some(p) => {
                labels.insert(label.to_string(), p);
            }
            None => {
                labels.remove(label);
                if labels.is_empty() {
                    map.remove(model);
                }
            }
        }
        true
    }

    /// Resolve `label` on `model` to its pinned version.
    pub fn resolve(&self, model: &str, label: &str) -> Result<u64> {
        let map = self.map.read().unwrap();
        match map.get(model).and_then(|labels| labels.get(label)) {
            Some(&v) => Ok(v),
            None => {
                let known: Vec<String> = map
                    .get(model)
                    .map(|l| l.keys().cloned().collect())
                    .unwrap_or_default();
                bail_kind!(
                    ErrorKind::NotFound,
                    "model '{model}' has no version labeled '{label}' (known labels: {known:?})"
                )
            }
        }
    }

    /// Remove one label. Returns whether it existed.
    pub fn remove(&self, model: &str, label: &str) -> bool {
        self.map
            .write()
            .unwrap()
            .get_mut(model)
            .map(|labels| labels.remove(label).is_some())
            .unwrap_or(false)
    }

    /// Drop every label of `model` pointing at `version` and return
    /// them (sorted by label). The server's unload path calls this so
    /// labels never dangle on an unloaded version — a labeled lookup
    /// after GC reports "no version labeled …" instead of failing on a
    /// version that quietly left the serving map.
    pub fn remove_version(&self, model: &str, version: u64) -> Vec<String> {
        let mut map = self.map.write().unwrap();
        let Some(labels) = map.get_mut(model) else {
            return Vec::new();
        };
        let doomed: Vec<String> = labels
            .iter()
            .filter(|(_, &v)| v == version)
            .map(|(k, _)| k.clone())
            .collect();
        for label in &doomed {
            labels.remove(label);
        }
        if labels.is_empty() {
            map.remove(model);
        }
        doomed
    }

    /// All `(label, version)` pairs for a model, sorted by label.
    pub fn labels(&self, model: &str) -> Vec<(String, u64)> {
        self.map
            .read()
            .unwrap()
            .get(model)
            .map(|l| l.iter().map(|(k, &v)| (k.clone(), v)).collect())
            .unwrap_or_default()
    }

    /// Labels currently attached to one specific version of a model.
    pub fn labels_of_version(&self, model: &str, version: u64) -> Vec<String> {
        self.map
            .read()
            .unwrap()
            .get(model)
            .map(|l| {
                l.iter()
                    .filter(|(_, &v)| v == version)
                    .map(|(k, _)| k.clone())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_resolve_roundtrip() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1, 2]).unwrap();
        r.set("m", "canary", 2, &[1, 2]).unwrap();
        assert_eq!(r.resolve("m", "stable").unwrap(), 1);
        assert_eq!(r.resolve("m", "canary").unwrap(), 2);
        assert_eq!(
            r.labels("m"),
            vec![("canary".to_string(), 2), ("stable".to_string(), 1)]
        );
        assert_eq!(r.labels_of_version("m", 2), vec!["canary".to_string()]);
    }

    #[test]
    fn unknown_label_errors_and_lists_known() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1]).unwrap();
        let err = r.resolve("m", "canary").unwrap_err().to_string();
        assert!(err.contains("canary") && err.contains("stable"), "{err}");
        // Unknown model too.
        let err = r.resolve("ghost", "stable").unwrap_err().to_string();
        assert!(err.contains("ghost"), "{err}");
    }

    #[test]
    fn labeling_unserved_version_rejected() {
        let r = LabelResolver::new();
        let err = r.set("m", "canary", 9, &[1, 2]).unwrap_err().to_string();
        assert!(err.contains("not loaded and serving"), "{err}");
        assert!(r.resolve("m", "canary").is_err());
        // Empty label rejected too.
        assert!(r.set("m", "", 1, &[1]).is_err());
    }

    #[test]
    fn relabel_during_serving_moves_the_pointer() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1, 2]).unwrap();
        assert_eq!(r.resolve("m", "stable").unwrap(), 1);
        // Promote: stable now points at v2.
        r.set("m", "stable", 2, &[1, 2]).unwrap();
        assert_eq!(r.resolve("m", "stable").unwrap(), 2);
        assert_eq!(r.labels("m").len(), 1);
    }

    #[test]
    fn remove_label() {
        let r = LabelResolver::new();
        r.set("m", "canary", 1, &[1]).unwrap();
        assert!(r.remove("m", "canary"));
        assert!(!r.remove("m", "canary"));
        assert!(r.resolve("m", "canary").is_err());
    }

    #[test]
    fn rollback_is_compare_and_swap() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1, 2, 3]).unwrap();
        // Move stable→2, then roll the move back: v1 restored.
        assert_eq!(r.set("m", "stable", 2, &[1, 2, 3]).unwrap(), Some(1));
        assert!(r.rollback("m", "stable", 2, Some(1)));
        assert_eq!(r.resolve("m", "stable").unwrap(), 1);
        // A label that moved on (concurrent admin) is left alone.
        r.set("m", "stable", 3, &[1, 2, 3]).unwrap();
        assert!(!r.rollback("m", "stable", 2, Some(1)));
        assert_eq!(r.resolve("m", "stable").unwrap(), 3);
        // Rollback with no prior mapping drops the label.
        assert_eq!(r.set("m", "fresh", 2, &[1, 2, 3]).unwrap(), None);
        assert!(r.rollback("m", "fresh", 2, None));
        assert!(r.resolve("m", "fresh").is_err());
        // Unknown model: no-op.
        assert!(!r.rollback("ghost", "stable", 1, None));
    }

    #[test]
    fn remove_version_drops_every_label_on_it() {
        let r = LabelResolver::new();
        r.set("m", "stable", 1, &[1, 2]).unwrap();
        r.set("m", "canary", 2, &[1, 2]).unwrap();
        r.set("m", "head", 2, &[1, 2]).unwrap();
        // GC of v2 drops both of its labels, leaves v1's alone.
        assert_eq!(
            r.remove_version("m", 2),
            vec!["canary".to_string(), "head".to_string()]
        );
        assert!(r.resolve("m", "canary").is_err());
        assert!(r.resolve("m", "head").is_err());
        assert_eq!(r.resolve("m", "stable").unwrap(), 1);
        // No labels on the version / unknown model: empty, no panic.
        assert!(r.remove_version("m", 2).is_empty());
        assert!(r.remove_version("ghost", 1).is_empty());
        // GC of the last label removes the model entry entirely.
        assert_eq!(r.remove_version("m", 1), vec!["stable".to_string()]);
        assert!(r.labels("m").is_empty());
    }

    #[test]
    fn models_are_independent() {
        let r = LabelResolver::new();
        r.set("a", "stable", 1, &[1]).unwrap();
        r.set("b", "stable", 2, &[2]).unwrap();
        assert_eq!(r.resolve("a", "stable").unwrap(), 1);
        assert_eq!(r.resolve("b", "stable").unwrap(), 2);
    }
}
