//! Version transition policies (§2.1.2).
//!
//! Given the aspired set and the currently-serving set for one servable,
//! a policy picks the *next single action* (load X or unload Y). The
//! [`super::manager::AspiredVersionsManager`] applies actions one at a
//! time so policies fully control interleaving:
//!
//! * [`AvailabilityPreservingPolicy`] — load new versions *before*
//!   unloading old ones: availability never lapses, at the cost of peak
//!   RAM holding both versions ("(1)" in the paper).
//! * [`ResourcePreservingPolicy`] — unload *before* loading: at most one
//!   version resident, with an availability gap ("(2)"; for models so
//!   large two versions cannot fit, with replicas or retrying batch
//!   clients absorbing the lapse).

/// The next lifecycle action for one servable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    Load(u64),
    Unload(u64),
}

/// Picks at most one action per reconciliation step.
pub trait VersionPolicy: Send + Sync {
    /// `aspired`: versions the source wants resident.
    /// `serving`: versions currently Ready (or becoming ready).
    fn next_action(&self, aspired: &[u64], serving: &[u64]) -> Option<Action>;

    fn name(&self) -> &'static str;
}

/// Load-before-unload (paper policy 1).
pub struct AvailabilityPreservingPolicy;

impl VersionPolicy for AvailabilityPreservingPolicy {
    fn next_action(&self, aspired: &[u64], serving: &[u64]) -> Option<Action> {
        // 1. Load any aspired version not yet serving (highest first, so
        //    the newest becomes available soonest).
        if let Some(&v) = aspired.iter().filter(|v| !serving.contains(v)).max() {
            return Some(Action::Load(v));
        }
        // 2. Only once every aspired version serves, unload non-aspired
        //    (lowest first).
        if let Some(&v) = serving.iter().filter(|v| !aspired.contains(v)).min() {
            return Some(Action::Unload(v));
        }
        None
    }

    fn name(&self) -> &'static str {
        "availability_preserving"
    }
}

/// Unload-before-load (paper policy 2).
pub struct ResourcePreservingPolicy;

impl VersionPolicy for ResourcePreservingPolicy {
    fn next_action(&self, aspired: &[u64], serving: &[u64]) -> Option<Action> {
        // 1. Unload anything not aspired (free resources first).
        if let Some(&v) = serving.iter().filter(|v| !aspired.contains(v)).min() {
            return Some(Action::Unload(v));
        }
        // 2. Then load missing aspired versions (highest first).
        if let Some(&v) = aspired.iter().filter(|v| !serving.contains(v)).max() {
            return Some(Action::Load(v));
        }
        None
    }

    fn name(&self) -> &'static str {
        "resource_preserving"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    /// Drive a policy to fixpoint from `serving` toward `aspired`,
    /// recording the serving set after every action.
    fn run_to_fixpoint(
        policy: &dyn VersionPolicy,
        aspired: &[u64],
        serving: &[u64],
    ) -> Vec<Vec<u64>> {
        let mut serving: Vec<u64> = serving.to_vec();
        let mut trace = vec![serving.clone()];
        for _ in 0..100 {
            match policy.next_action(aspired, &serving) {
                Some(Action::Load(v)) => serving.push(v),
                Some(Action::Unload(v)) => serving.retain(|&x| x != v),
                None => return trace,
            }
            serving.sort_unstable();
            trace.push(serving.clone());
        }
        panic!("policy did not converge: aspired={aspired:?}");
    }

    #[test]
    fn availability_loads_before_unloading() {
        let p = AvailabilityPreservingPolicy;
        // Version transition 1 -> 2.
        assert_eq!(p.next_action(&[2], &[1]), Some(Action::Load(2)));
        assert_eq!(p.next_action(&[2], &[1, 2]), Some(Action::Unload(1)));
        assert_eq!(p.next_action(&[2], &[2]), None);
    }

    #[test]
    fn resource_unloads_before_loading() {
        let p = ResourcePreservingPolicy;
        assert_eq!(p.next_action(&[2], &[1]), Some(Action::Unload(1)));
        assert_eq!(p.next_action(&[2], &[]), Some(Action::Load(2)));
        assert_eq!(p.next_action(&[2], &[2]), None);
    }

    #[test]
    fn canary_aspires_two_versions() {
        // §2.1.1: aspire both newest and second-newest.
        let p = AvailabilityPreservingPolicy;
        assert_eq!(p.next_action(&[1, 2], &[1]), Some(Action::Load(2)));
        assert_eq!(p.next_action(&[1, 2], &[1, 2]), None);
        // End canary: drop v1.
        assert_eq!(p.next_action(&[2], &[1, 2]), Some(Action::Unload(1)));
    }

    #[test]
    fn rollback_returns_to_older_version() {
        // §2.1.1: aspire specific older version 1 while 2 is serving.
        let p = AvailabilityPreservingPolicy;
        assert_eq!(p.next_action(&[1], &[2]), Some(Action::Load(1)));
        assert_eq!(p.next_action(&[1], &[1, 2]), Some(Action::Unload(2)));
    }

    #[test]
    fn availability_never_empty_during_transition() {
        // Property: starting non-empty with non-empty aspired set, the
        // serving set never becomes empty mid-transition.
        forall::<(Vec<u64>, Vec<u64>), _>("availability preserved", |(a, s)| {
            let aspired: Vec<u64> = {
                let mut a: Vec<u64> = a.iter().map(|x| x % 8).collect();
                a.sort_unstable();
                a.dedup();
                a
            };
            let serving: Vec<u64> = {
                let mut s: Vec<u64> = s.iter().map(|x| x % 8).collect();
                s.sort_unstable();
                s.dedup();
                s
            };
            if aspired.is_empty() || serving.is_empty() {
                return true; // vacuous: nothing to keep available
            }
            let trace = run_to_fixpoint(&AvailabilityPreservingPolicy, &aspired, &serving);
            trace.iter().all(|step| !step.is_empty())
        });
    }

    #[test]
    fn resource_never_exceeds_peak_plus_zero() {
        // Property: resource policy never holds a non-aspired version
        // and a newly-loaded one simultaneously: serving set size never
        // exceeds max(|serving ∩ aspired| at start, |aspired|).
        forall::<(Vec<u64>, Vec<u64>), _>("resource bounded", |(a, s)| {
            let aspired: Vec<u64> = {
                let mut a: Vec<u64> = a.iter().map(|x| x % 8).collect();
                a.sort_unstable();
                a.dedup();
                a
            };
            let serving: Vec<u64> = {
                let mut s: Vec<u64> = s.iter().map(|x| x % 8).collect();
                s.sort_unstable();
                s.dedup();
                s
            };
            let bound = aspired.len().max(serving.len());
            let trace = run_to_fixpoint(&ResourcePreservingPolicy, &aspired, &serving);
            trace.iter().all(|step| step.len() <= bound)
        });
    }

    #[test]
    fn both_policies_converge_to_aspired() {
        forall::<(Vec<u64>, Vec<u64>, bool), _>("converges", |(a, s, avail)| {
            let aspired: Vec<u64> = {
                let mut a: Vec<u64> = a.iter().map(|x| x % 6).collect();
                a.sort_unstable();
                a.dedup();
                a
            };
            let serving: Vec<u64> = {
                let mut s: Vec<u64> = s.iter().map(|x| x % 6).collect();
                s.sort_unstable();
                s.dedup();
                s
            };
            let policy: &dyn VersionPolicy = if *avail {
                &AvailabilityPreservingPolicy
            } else {
                &ResourcePreservingPolicy
            };
            let trace = run_to_fixpoint(policy, &aspired, &serving);
            trace.last().unwrap() == &aspired
        });
    }
}
