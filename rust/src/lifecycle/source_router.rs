//! Source Routers: split one aspired-version stream into several (§2.1,
//! Figure 1) — e.g. TensorFlow models to the TensorFlow adapter,
//! BananaFlow models to the BananaFlow adapter, in the same server.

use crate::base::aspired::{AspiredVersionsCallback, ServableData};
use std::sync::{Arc, Mutex};

/// Routes each servable's stream to one of N output ports by name.
pub struct SourceRouter<T> {
    route: Box<dyn Fn(&str) -> usize + Send + Sync>,
    ports: Vec<Mutex<Option<Arc<dyn AspiredVersionsCallback<T>>>>>,
}

impl<T: Send + 'static> SourceRouter<T> {
    /// `route(name)` returns the output port index; out-of-range values
    /// drop the stream (with a warning), matching TF-Serving's
    /// "default port" escape hatch when clamped by the caller.
    pub fn new<F>(num_ports: usize, route: F) -> Arc<Self>
    where
        F: Fn(&str) -> usize + Send + Sync + 'static,
    {
        Arc::new(SourceRouter {
            route: Box::new(route),
            ports: (0..num_ports).map(|_| Mutex::new(None)).collect(),
        })
    }

    pub fn connect_port(&self, port: usize, downstream: Arc<dyn AspiredVersionsCallback<T>>) {
        *self.ports[port].lock().unwrap() = Some(downstream);
    }

    pub fn num_ports(&self) -> usize {
        self.ports.len()
    }
}

impl<T: Send + 'static> AspiredVersionsCallback<T> for SourceRouter<T> {
    fn set_aspired_versions(&self, servable_name: &str, versions: Vec<ServableData<T>>) {
        let port = (self.route)(servable_name);
        match self.ports.get(port) {
            Some(slot) => {
                if let Some(downstream) = slot.lock().unwrap().clone() {
                    downstream.set_aspired_versions(servable_name, versions);
                } else {
                    crate::log_warn!("router port {port} unconnected; dropping '{servable_name}'");
                }
            }
            None => {
                crate::log_warn!(
                    "router: no port {port} for '{servable_name}' (have {})",
                    self.ports.len()
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::aspired::RecordingCallback;
    use crate::base::servable::ServableId;

    fn data(name: &str, v: u64) -> Vec<ServableData<u32>> {
        vec![ServableData::ok(ServableId::new(name, v), 0u32)]
    }

    #[test]
    fn routes_by_name() {
        // Port 0: TensorFlow-ish, port 1: BananaFlow-ish.
        let router =
            SourceRouter::<u32>::new(2, |name| usize::from(name.starts_with("banana")));
        let tf = RecordingCallback::<u32>::new();
        let banana = RecordingCallback::<u32>::new();
        router.connect_port(0, tf.clone());
        router.connect_port(1, banana.clone());

        router.set_aspired_versions("mnist", data("mnist", 1));
        router.set_aspired_versions("banana_ranker", data("banana_ranker", 2));

        assert_eq!(tf.latest_for("mnist"), Some(vec![1]));
        assert_eq!(tf.latest_for("banana_ranker"), None);
        assert_eq!(banana.latest_for("banana_ranker"), Some(vec![2]));
    }

    #[test]
    fn out_of_range_port_drops() {
        let router = SourceRouter::<u32>::new(1, |_| 7);
        let sink = RecordingCallback::<u32>::new();
        router.connect_port(0, sink.clone());
        router.set_aspired_versions("m", data("m", 1));
        assert_eq!(sink.call_count(), 0);
    }

    #[test]
    fn unconnected_port_drops() {
        let router = SourceRouter::<u32>::new(2, |_| 1);
        let sink = RecordingCallback::<u32>::new();
        router.connect_port(0, sink.clone());
        router.set_aspired_versions("m", data("m", 1));
        assert_eq!(sink.call_count(), 0);
    }
}
