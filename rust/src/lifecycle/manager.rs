//! [`AspiredVersionsManager`] — the paper's flagship Manager (§2.1.2).
//!
//! It terminates the aspired-versions chain: Sources (via adapters)
//! call [`AspiredVersionsCallback::set_aspired_versions`] with
//! `Arc<dyn Loader>` payloads; a reconciliation thread diffs aspired
//! state against serving state and executes one [`policy`] action per
//! servable per tick through the underlying
//! [`BasicManager`](super::basic_manager::BasicManager) (RCU serving
//! map, isolated load pool, deferred reclamation).

use super::basic_manager::{BasicManager, ManagerOptions, VersionRequest};
use super::harness::State;
use super::monitor::ServableStateMonitor;
use super::policy::{Action, VersionPolicy};
use crate::base::aspired::{AspiredVersionsCallback, ServableData};
use crate::base::loader::Loader;
use crate::base::servable::{ServableHandle, ServableId};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Aspired state for one servable name.
struct AspiredEntry {
    /// version → loader. The full desired set (idempotent API).
    versions: HashMap<u64, Arc<dyn Loader>>,
}

/// Options for [`AspiredVersionsManager`].
#[derive(Clone)]
pub struct AvmOptions {
    pub manager: ManagerOptions,
    /// Period of the background reconcile thread; `None` = manual
    /// reconciliation only (deterministic tests).
    pub reconcile_interval: Option<Duration>,
    /// Times a version whose load ended in `Error` is re-attempted
    /// (with exponential backoff) while the previously-serving version
    /// keeps serving. `0` = never retry: a failed load parks in
    /// `Error` until the source emits new state — the conservative
    /// default.
    pub num_load_retries: u32,
    /// Backoff before the first retry; doubles on each subsequent
    /// attempt.
    pub load_retry_backoff: Duration,
}

impl Default for AvmOptions {
    fn default() -> Self {
        AvmOptions {
            manager: ManagerOptions::default(),
            reconcile_interval: Some(Duration::from_millis(20)),
            num_load_retries: 0,
            load_retry_backoff: Duration::from_millis(100),
        }
    }
}

/// Per-version retry bookkeeping (versions currently in `Error` with
/// retry budget left).
struct RetryState {
    attempts: u32,
    next_attempt_at: std::time::Instant,
}

pub struct AspiredVersionsManager {
    basic: Arc<BasicManager>,
    policy: Arc<dyn VersionPolicy>,
    aspired: Mutex<HashMap<String, AspiredEntry>>,
    /// Versions currently mid-action (loading or unloading), so a tick
    /// doesn't double-issue while the BasicManager works asynchronously.
    in_flight: Mutex<HashMap<ServableId, Action>>,
    /// Errored versions awaiting a backoff-gated load retry.
    retries: Mutex<HashMap<ServableId, RetryState>>,
    num_load_retries: u32,
    load_retry_backoff: Duration,
    stop: AtomicBool,
    ticker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl AspiredVersionsManager {
    pub fn new(policy: Arc<dyn VersionPolicy>, options: AvmOptions) -> Arc<Self> {
        let avm = Arc::new(AspiredVersionsManager {
            basic: BasicManager::new(options.manager.clone()),
            policy,
            aspired: Mutex::new(HashMap::new()),
            in_flight: Mutex::new(HashMap::new()),
            retries: Mutex::new(HashMap::new()),
            num_load_retries: options.num_load_retries,
            load_retry_backoff: options.load_retry_backoff,
            stop: AtomicBool::new(false),
            ticker: Mutex::new(None),
        });
        if let Some(interval) = options.reconcile_interval {
            let weak = Arc::downgrade(&avm);
            let handle = std::thread::Builder::new()
                .name(format!("{}-reconcile", options.manager.name))
                .spawn(move || loop {
                    std::thread::sleep(interval);
                    match weak.upgrade() {
                        Some(avm) => {
                            if avm.stop.load(Ordering::SeqCst) {
                                return;
                            }
                            avm.reconcile();
                        }
                        None => return,
                    }
                })
                .expect("spawn reconcile thread");
            *avm.ticker.lock().unwrap() = Some(handle);
        }
        avm
    }

    /// Underlying executor (for handles, monitor, metrics).
    pub fn basic(&self) -> &Arc<BasicManager> {
        &self.basic
    }

    pub fn monitor(&self) -> &Arc<ServableStateMonitor> {
        self.basic.monitor()
    }

    /// One reconciliation pass: for each servable, compare aspired vs
    /// serving and issue at most one policy action.
    pub fn reconcile(self: &Arc<Self>) {
        // Drop finished in-flight actions.
        {
            let monitor = self.basic.monitor();
            let mut inflight = self.in_flight.lock().unwrap();
            inflight.retain(|id, action| match monitor.state_of(id) {
                Some(State::Ready) => !matches!(action, Action::Load(_)),
                Some(State::Disabled) | Some(State::Error(_)) => false,
                None => false,
                _ => true, // still loading/unloading
            });
        }

        let aspired_snapshot: Vec<(String, Vec<u64>)> = {
            let aspired = self.aspired.lock().unwrap();
            aspired
                .iter()
                .map(|(name, e)| {
                    let mut v: Vec<u64> = e.versions.keys().copied().collect();
                    v.sort_unstable();
                    (name.clone(), v)
                })
                .collect()
        };

        for (name, mut aspired_versions) in aspired_snapshot {
            // Errored versions with retry budget left get forgotten and
            // re-issued once their backoff elapses; the rest are dropped
            // from the aspired set (parked in `Error` until the source
            // emits new state) so one broken version can't wedge the
            // others.
            let raw_aspired_len = aspired_versions.len();
            let retry_now = self.schedule_retries(&name, &aspired_versions);
            {
                let monitor = self.basic.monitor();
                aspired_versions.retain(|v| {
                    retry_now.contains(v)
                        || !matches!(
                            monitor.state_of(&ServableId::new(name.clone(), *v)),
                            Some(State::Error(_))
                        )
                });
            }
            // If EVERY aspired version failed (but the source does want
            // versions), keep serving whatever we have: unloading now
            // would take availability to zero chasing a broken update.
            // An explicitly-empty aspired list still unloads everything.
            if aspired_versions.is_empty() && raw_aspired_len > 0 {
                continue;
            }
            // The policy sees only *actually ready* versions (minus
            // in-flight unloads). In-flight loads must NOT count as
            // serving: availability-preserving would otherwise unload
            // the old version while the new one is still loading (or
            // about to fail). Double-issue is prevented in `execute`
            // by the in_flight check instead.
            let mut serving = self.basic.ready_versions(&name);
            {
                let inflight = self.in_flight.lock().unwrap();
                for (id, action) in inflight.iter() {
                    if id.name == name {
                        if let Action::Unload(v) = action {
                            serving.retain(|x| x != v);
                        }
                    }
                }
            }
            serving.sort_unstable();

            if let Some(action) = self.policy.next_action(&aspired_versions, &serving) {
                self.execute(&name, action);
            }
        }
    }

    /// Backoff-gated load retry (the tentpole's lifecycle leg). For
    /// each aspired version parked in `Error` with attempts left and an
    /// elapsed backoff, forget the errored harness (freeing the id for
    /// a fresh `manage_and_load`) and report it as retryable so the
    /// caller keeps it in the aspired set — the policy then re-issues
    /// `Load` through the normal path while the previously-serving
    /// version keeps serving. Versions that reach `Ready` (or leave the
    /// error state) get their bookkeeping cleared so a *later* failure
    /// starts with a full budget again.
    fn schedule_retries(&self, name: &str, aspired: &[u64]) -> Vec<u64> {
        if self.num_load_retries == 0 {
            return Vec::new();
        }
        let monitor = self.basic.monitor();
        let mut retries = self.retries.lock().unwrap();
        let now = std::time::Instant::now();
        let mut retry_now = Vec::new();
        for &v in aspired {
            let id = ServableId::new(name, v);
            if !matches!(monitor.state_of(&id), Some(State::Error(_))) {
                if matches!(monitor.state_of(&id), Some(State::Ready)) {
                    retries.remove(&id);
                }
                continue;
            }
            let entry = retries.entry(id.clone()).or_insert(RetryState {
                attempts: 0,
                // First sighting of the error: wait one backoff before
                // retrying (the failure is fresh; hammering it helps no
                // one).
                next_attempt_at: now + self.load_retry_backoff,
            });
            if entry.attempts >= self.num_load_retries || now < entry.next_attempt_at {
                continue;
            }
            if self.basic.forget_errored(&id) {
                entry.attempts += 1;
                entry.next_attempt_at =
                    now + self.load_retry_backoff.saturating_mul(1u32 << entry.attempts.min(16));
                crate::log_info!(
                    "{id}: retrying failed load (attempt {}/{})",
                    entry.attempts,
                    self.num_load_retries
                );
                retry_now.push(v);
            }
        }
        retry_now
    }

    fn execute(self: &Arc<Self>, name: &str, action: Action) {
        let id = match action {
            Action::Load(v) | Action::Unload(v) => ServableId::new(name, v),
        };
        {
            let mut inflight = self.in_flight.lock().unwrap();
            if inflight.contains_key(&id) {
                return;
            }
            inflight.insert(id.clone(), action);
        }
        let result: Result<()> = match action {
            Action::Load(v) => {
                let loader = self
                    .aspired
                    .lock()
                    .unwrap()
                    .get(name)
                    .and_then(|e| e.versions.get(&v).cloned());
                match loader {
                    Some(loader) => self.basic.manage_and_load(id.clone(), loader),
                    None => Ok(()), // aspired state changed mid-tick
                }
            }
            Action::Unload(_) => self.basic.unload(id.clone()),
        };
        if result.is_err() {
            self.in_flight.lock().unwrap().remove(&id);
        }
    }

    /// Drive reconciliation until aspired == serving or `max_ticks`.
    /// For deterministic tests and synchronous bring-up.
    pub fn reconcile_until_stable(self: &Arc<Self>, max_ticks: usize) -> bool {
        for _ in 0..max_ticks {
            self.reconcile();
            self.basic.quiesce();
            self.reconcile(); // clear finished in-flight entries
            if self.is_stable() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        self.is_stable()
    }

    /// True when every aspired version is ready and nothing extra is.
    pub fn is_stable(&self) -> bool {
        let aspired = self.aspired.lock().unwrap();
        for (name, e) in aspired.iter() {
            let mut want: Vec<u64> = e.versions.keys().copied().collect();
            want.sort_unstable();
            let mut have = self.basic.ready_versions(name);
            // Versions that failed to load permanently don't count
            // against stability (they're surfaced via the monitor).
            let monitor = self.basic.monitor();
            want.retain(|v| {
                !matches!(
                    monitor.state_of(&ServableId::new(name.clone(), *v)),
                    Some(State::Error(_))
                )
            });
            have.sort_unstable();
            if want != have {
                return false;
            }
        }
        true
    }

    /// Typed handle lookup (delegates to the RCU map).
    pub fn handle<T: Send + Sync + 'static>(
        &self,
        name: &str,
        version: VersionRequest,
    ) -> Result<ServableHandle<T>> {
        self.basic.handle(name, version)
    }
}

impl Drop for AspiredVersionsManager {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.ticker.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl AspiredVersionsCallback<Arc<dyn Loader>> for AspiredVersionsManager {
    fn set_aspired_versions(
        &self,
        servable_name: &str,
        versions: Vec<ServableData<Arc<dyn Loader>>>,
    ) {
        let mut map = HashMap::new();
        for data in versions {
            match data.payload {
                Ok(loader) => {
                    map.insert(data.id.version, loader);
                }
                Err(e) => {
                    crate::log_warn!("{}: dropped errored aspired version: {e}", data.id);
                }
            }
        }
        // Versions no longer aspired don't need retry bookkeeping; a
        // re-aspired version starts with a fresh budget.
        self.retries
            .lock()
            .unwrap()
            .retain(|id, _| id.name != servable_name || map.contains_key(&id.version));
        self.aspired
            .lock()
            .unwrap()
            .insert(servable_name.to_string(), AspiredEntry { versions: map });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::FnLoader;
    use crate::lifecycle::policy::{AvailabilityPreservingPolicy, ResourcePreservingPolicy};

    fn avm(policy: Arc<dyn VersionPolicy>) -> Arc<AspiredVersionsManager> {
        AspiredVersionsManager::new(
            policy,
            AvmOptions { reconcile_interval: None, ..Default::default() },
        )
    }

    fn aspire(m: &Arc<AspiredVersionsManager>, name: &str, versions: &[(u64, u32)]) {
        let data = versions
            .iter()
            .map(|&(v, val)| {
                ServableData::ok(
                    ServableId::new(name, v),
                    Arc::new(FnLoader::constant(val)) as Arc<dyn Loader>,
                )
            })
            .collect();
        m.set_aspired_versions(name, data);
    }

    #[test]
    fn loads_aspired_versions() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(*m.handle::<u32>("m", VersionRequest::Latest).unwrap(), 10);
    }

    #[test]
    fn version_transition_availability_preserving() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));

        // New version arrives; aspire only v2.
        aspire(&m, "m", &[(2, 20)]);
        // After ONE action (load v2), both versions must be ready —
        // availability-preserving keeps v1 until v2 serves.
        m.reconcile();
        m.basic().quiesce();
        assert_eq!(m.basic().ready_versions("m"), vec![1, 2]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(m.basic().ready_versions("m"), vec![2]);
        assert_eq!(*m.handle::<u32>("m", VersionRequest::Latest).unwrap(), 20);
    }

    #[test]
    fn version_transition_resource_preserving() {
        let m = avm(Arc::new(ResourcePreservingPolicy));
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));

        aspire(&m, "m", &[(2, 20)]);
        // First action unloads v1: availability lapse, bounded memory.
        m.reconcile();
        m.basic().quiesce();
        assert_eq!(m.basic().ready_versions("m"), Vec::<u64>::new());
        assert!(m.reconcile_until_stable(20));
        assert_eq!(m.basic().ready_versions("m"), vec![2]);
    }

    #[test]
    fn canary_then_end_canary() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));
        // Canary: aspire both.
        aspire(&m, "m", &[(1, 10), (2, 20)]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(m.basic().ready_versions("m"), vec![1, 2]);
        // Promote: aspire only v2.
        aspire(&m, "m", &[(2, 20)]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(m.basic().ready_versions("m"), vec![2]);
    }

    #[test]
    fn rollback_to_older_version() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        aspire(&m, "m", &[(2, 20)]);
        assert!(m.reconcile_until_stable(20));
        // Rollback: aspire v1 only.
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(m.basic().ready_versions("m"), vec![1]);
        assert_eq!(*m.handle::<u32>("m", VersionRequest::Latest).unwrap(), 10);
    }

    #[test]
    fn empty_aspired_unloads_all() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        aspire(&m, "m", &[(1, 10), (2, 20)]);
        assert!(m.reconcile_until_stable(20));
        m.set_aspired_versions("m", vec![]);
        assert!(m.reconcile_until_stable(20));
        assert!(m.basic().ready_versions("m").is_empty());
    }

    #[test]
    fn failed_loads_do_not_wedge_reconciliation() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        m.set_aspired_versions(
            "m",
            vec![
                ServableData::ok(
                    ServableId::new("m", 1),
                    Arc::new(FnLoader::constant(10u32)) as Arc<dyn Loader>,
                ),
                ServableData::ok(
                    ServableId::new("m", 2),
                    Arc::new(FnLoader::failing("broken")) as Arc<dyn Loader>,
                ),
            ],
        );
        assert!(m.reconcile_until_stable(30));
        // v1 serves; v2 is in Error.
        assert_eq!(m.basic().ready_versions("m"), vec![1]);
        assert!(matches!(
            m.monitor().state_of(&ServableId::new("m", 2)),
            Some(State::Error(_))
        ));
    }

    /// Tentpole: a transiently failing load is retried with backoff at
    /// the AVM level while the previous version keeps serving, and
    /// converges to Ready once the fault clears.
    #[test]
    fn load_retry_with_backoff_recovers_transient_failure() {
        use crate::base::loader::ResourceEstimate;
        use crate::base::servable::ServableBox;
        use std::sync::atomic::{AtomicU32, Ordering};

        let calls = Arc::new(AtomicU32::new(0));
        let c = Arc::clone(&calls);
        let flaky = FnLoader::new(ResourceEstimate::default(), "flaky", move || {
            if c.fetch_add(1, Ordering::SeqCst) < 2 {
                anyhow::bail!("transient store outage");
            }
            Ok(Arc::new(20u32) as ServableBox)
        });
        let m = AspiredVersionsManager::new(
            Arc::new(AvailabilityPreservingPolicy),
            AvmOptions {
                reconcile_interval: None,
                num_load_retries: 2,
                load_retry_backoff: Duration::from_millis(1),
                // Harness-level retries off so each AVM attempt is
                // exactly one loader call (deterministic counting).
                manager: ManagerOptions {
                    harness: crate::lifecycle::harness::HarnessOptions { max_load_retries: 0 },
                    ..Default::default()
                },
            },
        );
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));

        m.set_aspired_versions(
            "m",
            vec![
                ServableData::ok(
                    ServableId::new("m", 1),
                    Arc::new(FnLoader::constant(10u32)) as Arc<dyn Loader>,
                ),
                ServableData::ok(ServableId::new("m", 2), Arc::new(flaky) as Arc<dyn Loader>),
            ],
        );
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            m.reconcile();
            m.basic().quiesce();
            // Availability is never sacrificed while chasing v2.
            assert!(m.basic().ready_versions("m").contains(&1), "v1 dropped mid-retry");
            if m.basic().ready_versions("m").contains(&2) {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "v2 never recovered; state: {:?}",
                m.monitor().state_of(&ServableId::new("m", 2))
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        // Initial attempt + two AVM retries, the last of which succeeds.
        assert_eq!(calls.load(Ordering::SeqCst), 3);
        assert_eq!(*m.handle::<u32>("m", VersionRequest::Latest).unwrap(), 20);
    }

    /// When the retry budget runs out the version parks in `Error`
    /// (exactly as with retries disabled) and stops consuming loads.
    #[test]
    fn load_retry_budget_exhausts_then_parks() {
        use crate::base::loader::ResourceEstimate;
        use crate::base::servable::ServableBox;
        use std::sync::atomic::{AtomicU32, Ordering};

        let calls = Arc::new(AtomicU32::new(0));
        let c = Arc::clone(&calls);
        let broken = FnLoader::new(ResourceEstimate::default(), "broken", move || {
            c.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("permanent corruption");
        });
        let m = AspiredVersionsManager::new(
            Arc::new(AvailabilityPreservingPolicy),
            AvmOptions {
                reconcile_interval: None,
                num_load_retries: 1,
                load_retry_backoff: Duration::from_millis(1),
                manager: ManagerOptions {
                    harness: crate::lifecycle::harness::HarnessOptions { max_load_retries: 0 },
                    ..Default::default()
                },
            },
        );
        aspire(&m, "m", &[(1, 10)]);
        assert!(m.reconcile_until_stable(20));
        m.set_aspired_versions(
            "m",
            vec![
                ServableData::ok(
                    ServableId::new("m", 1),
                    Arc::new(FnLoader::constant(10u32)) as Arc<dyn Loader>,
                ),
                ServableData::ok(ServableId::new("m", 2), Arc::new(broken) as Arc<dyn Loader>),
            ],
        );
        // Plenty of ticks for the initial attempt + one retry + any
        // would-be extras (there must be none).
        for _ in 0..20 {
            m.reconcile();
            m.basic().quiesce();
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(calls.load(Ordering::SeqCst), 2, "budget of 1 retry => 2 loads total");
        assert_eq!(m.basic().ready_versions("m"), vec![1]);
        assert!(matches!(
            m.monitor().state_of(&ServableId::new("m", 2)),
            Some(State::Error(_))
        ));
    }

    #[test]
    fn multiple_servables_independent() {
        let m = avm(Arc::new(AvailabilityPreservingPolicy));
        aspire(&m, "a", &[(1, 1)]);
        aspire(&m, "b", &[(5, 5)]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(*m.handle::<u32>("a", VersionRequest::Latest).unwrap(), 1);
        assert_eq!(*m.handle::<u32>("b", VersionRequest::Latest).unwrap(), 5);
        // Updating `a` leaves `b` alone.
        aspire(&m, "a", &[(2, 2)]);
        assert!(m.reconcile_until_stable(20));
        assert_eq!(*m.handle::<u32>("a", VersionRequest::Latest).unwrap(), 2);
        assert_eq!(*m.handle::<u32>("b", VersionRequest::Latest).unwrap(), 5);
    }

    #[test]
    fn background_ticker_reconciles() {
        let m = AspiredVersionsManager::new(
            Arc::new(AvailabilityPreservingPolicy),
            AvmOptions {
                reconcile_interval: Some(Duration::from_millis(5)),
                ..Default::default()
            },
        );
        aspire(&m, "m", &[(1, 10)]);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while std::time::Instant::now() < deadline {
            if m.basic().ready_versions("m") == vec![1] {
                return;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        panic!("background reconcile never loaded m:1");
    }
}
