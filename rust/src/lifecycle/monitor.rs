//! Servable state events and the [`ServableStateMonitor`].
//!
//! The manager publishes every harness state change on an event bus;
//! the monitor aggregates them so callers can ask "is m:2 ready?" or
//! block until it is (used at server startup and by the TFS²
//! Synchronizer's status reports).

use super::harness::State;
use crate::base::servable::ServableId;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A state-change event.
#[derive(Debug, Clone)]
pub struct StateEvent {
    pub id: ServableId,
    pub state: State,
}

/// Subscriber callback.
pub type EventSubscriber = Arc<dyn Fn(&StateEvent) + Send + Sync>;

/// Fan-out bus for state events.
#[derive(Default)]
pub struct EventBus {
    subscribers: Mutex<Vec<EventSubscriber>>,
}

impl EventBus {
    pub fn new() -> Arc<Self> {
        Arc::new(EventBus::default())
    }

    pub fn subscribe(&self, sub: EventSubscriber) {
        self.subscribers.lock().unwrap().push(sub);
    }

    pub fn publish(&self, event: StateEvent) {
        let subs = self.subscribers.lock().unwrap().clone();
        for s in subs {
            s(&event);
        }
    }
}

/// Live view of every servable version's state, with blocking waits.
pub struct ServableStateMonitor {
    states: Mutex<HashMap<ServableId, State>>,
    changed: Condvar,
}

impl ServableStateMonitor {
    /// Create and attach to a bus.
    pub fn attach(bus: &EventBus) -> Arc<Self> {
        let monitor = Arc::new(ServableStateMonitor {
            states: Mutex::new(HashMap::new()),
            changed: Condvar::new(),
        });
        let m = Arc::clone(&monitor);
        bus.subscribe(Arc::new(move |ev| m.observe(ev)));
        monitor
    }

    fn observe(&self, ev: &StateEvent) {
        let mut s = self.states.lock().unwrap();
        s.insert(ev.id.clone(), ev.state.clone());
        self.changed.notify_all();
    }

    pub fn state_of(&self, id: &ServableId) -> Option<State> {
        self.states.lock().unwrap().get(id).cloned()
    }

    /// Version numbers of `name` currently in `Ready`.
    pub fn ready_versions(&self, name: &str) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .states
            .lock()
            .unwrap()
            .iter()
            .filter(|(id, st)| id.name == name && **st == State::Ready)
            .map(|(id, _)| id.version)
            .collect();
        v.sort_unstable();
        v
    }

    /// Block until `id` reaches `Ready` or a terminal state, or timeout.
    /// Returns the final observed state (None on timeout with no info).
    pub fn wait_until_settled(&self, id: &ServableId, timeout: Duration) -> Option<State> {
        let deadline = Instant::now() + timeout;
        let mut s = self.states.lock().unwrap();
        loop {
            match s.get(id) {
                Some(st) if *st == State::Ready || st.is_terminal() => {
                    return Some(st.clone())
                }
                _ => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return s.get(id).cloned();
            }
            let (ns, res) = self.changed.wait_timeout(s, deadline - now).unwrap();
            s = ns;
            if res.timed_out() {
                return s.get(id).cloned();
            }
        }
    }

    /// Snapshot of all known states (diagnostics endpoint).
    pub fn snapshot(&self) -> Vec<(ServableId, State)> {
        let mut v: Vec<_> = self
            .states
            .lock()
            .unwrap()
            .iter()
            .map(|(k, s)| (k.clone(), s.clone()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn ev(name: &str, version: u64, state: State) -> StateEvent {
        StateEvent { id: ServableId::new(name, version), state }
    }

    #[test]
    fn monitor_tracks_states() {
        let bus = EventBus::new();
        let mon = ServableStateMonitor::attach(&bus);
        bus.publish(ev("m", 1, State::Loading));
        bus.publish(ev("m", 1, State::Ready));
        bus.publish(ev("m", 2, State::Loading));
        assert_eq!(mon.state_of(&ServableId::new("m", 1)), Some(State::Ready));
        assert_eq!(mon.ready_versions("m"), vec![1]);
        bus.publish(ev("m", 2, State::Ready));
        assert_eq!(mon.ready_versions("m"), vec![1, 2]);
        bus.publish(ev("m", 1, State::Unloading));
        assert_eq!(mon.ready_versions("m"), vec![2]);
    }

    #[test]
    fn wait_until_settled_blocks_until_ready() {
        let bus = EventBus::new();
        let mon = ServableStateMonitor::attach(&bus);
        let bus2 = Arc::clone(&bus);
        let t = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            bus2.publish(ev("m", 1, State::Ready));
        });
        let st = mon.wait_until_settled(&ServableId::new("m", 1), Duration::from_secs(5));
        t.join().unwrap();
        assert_eq!(st, Some(State::Ready));
    }

    #[test]
    fn wait_times_out() {
        let bus = EventBus::new();
        let mon = ServableStateMonitor::attach(&bus);
        bus.publish(ev("m", 1, State::Loading));
        let st =
            mon.wait_until_settled(&ServableId::new("m", 1), Duration::from_millis(30));
        assert_eq!(st, Some(State::Loading));
    }

    #[test]
    fn error_is_settled() {
        let bus = EventBus::new();
        let mon = ServableStateMonitor::attach(&bus);
        bus.publish(ev("m", 3, State::Error("boom".into())));
        let st = mon.wait_until_settled(&ServableId::new("m", 3), Duration::from_secs(1));
        assert!(matches!(st, Some(State::Error(_))));
    }

    #[test]
    fn multiple_subscribers() {
        let bus = EventBus::new();
        let count = Arc::new(Mutex::new(0));
        for _ in 0..3 {
            let c = Arc::clone(&count);
            bus.subscribe(Arc::new(move |_| *c.lock().unwrap() += 1));
        }
        bus.publish(ev("m", 1, State::New));
        assert_eq!(*count.lock().unwrap(), 3);
    }
}
