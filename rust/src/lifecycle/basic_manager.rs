//! [`BasicManager`]: executes loads/unloads and serves handles.
//!
//! This is the §2.1.2 machinery:
//! * **RCU serving map** — inference threads resolve `name[:version]` →
//!   servable with wait-free reads ([`crate::util::rcu`]).
//! * **Isolated load pool** — loads/unloads run on dedicated threads,
//!   never on inference threads.
//! * **Deferred reclamation** — unloaded servables (and handle refs) are
//!   dropped on a reclaim thread, followed by `malloc_trim`.
//! * **Resource admission** — a RAM ledger against an optional capacity,
//!   charged from pre-load [`ResourceEstimate`]s.
//! * **Parallel initial load** — "one-time use of all threads to load
//!   the initial set of servable versions, to speed up server start-up".
//!
//! [`super::manager::AspiredVersionsManager`] layers aspired-state
//! reconciliation on top.

use super::harness::{HarnessOptions, LoaderHarness, State};
use super::monitor::{EventBus, ServableStateMonitor, StateEvent};
use crate::base::loader::Loader;
use crate::base::reclaim::Reclaimer;
use crate::base::servable::{ServableBox, ServableHandle, ServableId};
use crate::util::rcu::Rcu;
use crate::util::threadpool::{ThreadPool, WaitGroup};
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which version of a servable a handle request wants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VersionRequest {
    Latest,
    Specific(u64),
}

/// name → version → ready servable. The value read on every request.
pub type ServingMap = HashMap<String, BTreeMap<u64, ServableBox>>;

/// Configuration for [`BasicManager`].
#[derive(Clone)]
pub struct ManagerOptions {
    /// Threads in the isolated load/unload pool.
    pub load_threads: usize,
    /// RAM capacity for admission control; `None` = unlimited.
    pub ram_capacity_bytes: Option<u64>,
    pub harness: HarnessOptions,
    /// Name used for thread names and logs.
    pub name: String,
}

impl Default for ManagerOptions {
    fn default() -> Self {
        ManagerOptions {
            load_threads: 2,
            ram_capacity_bytes: None,
            harness: HarnessOptions::default(),
            name: "manager".to_string(),
        }
    }
}

pub struct BasicManager {
    serving: Rcu<ServingMap>,
    harnesses: Mutex<HashMap<ServableId, Arc<LoaderHarness>>>,
    load_pool: ThreadPool,
    reclaimer: Reclaimer,
    bus: Arc<EventBus>,
    monitor: Arc<ServableStateMonitor>,
    ram_used: AtomicU64,
    options: ManagerOptions,
}

impl BasicManager {
    pub fn new(options: ManagerOptions) -> Arc<Self> {
        let bus = EventBus::new();
        let monitor = ServableStateMonitor::attach(&bus);
        Arc::new(BasicManager {
            serving: Rcu::new(ServingMap::new()),
            harnesses: Mutex::new(HashMap::new()),
            load_pool: ThreadPool::new(&format!("{}-load", options.name), options.load_threads),
            reclaimer: Reclaimer::start(&options.name),
            bus,
            monitor,
            ram_used: AtomicU64::new(0),
            options,
        })
    }

    pub fn with_defaults() -> Arc<Self> {
        Self::new(ManagerOptions::default())
    }

    pub fn bus(&self) -> &Arc<EventBus> {
        &self.bus
    }

    pub fn monitor(&self) -> &Arc<ServableStateMonitor> {
        &self.monitor
    }

    pub fn reclaimer(&self) -> &Reclaimer {
        &self.reclaimer
    }

    pub fn ram_used_bytes(&self) -> u64 {
        self.ram_used.load(Ordering::SeqCst)
    }

    fn publish(&self, id: &ServableId, state: State) {
        self.bus.publish(StateEvent { id: id.clone(), state });
    }

    // ------------------------------------------------------------- loads

    /// Start managing `id` and asynchronously load it on the load pool.
    ///
    /// Admission control happens here (synchronously): if the loader's
    /// RAM estimate does not fit the remaining capacity the load is
    /// rejected and the version goes straight to `Error`.
    pub fn manage_and_load(self: &Arc<Self>, id: ServableId, loader: Arc<dyn Loader>) -> Result<()> {
        let est = loader.estimate()?.ram_bytes;
        if let Some(cap) = self.options.ram_capacity_bytes {
            // Reserve with a CAS loop so concurrent admissions can't
            // oversubscribe.
            loop {
                let used = self.ram_used.load(Ordering::SeqCst);
                if used + est > cap {
                    self.publish(&id, State::Error("over RAM capacity".into()));
                    bail!(
                        "{id}: estimate {est}B over capacity ({used}/{cap}B used)"
                    );
                }
                if self
                    .ram_used
                    .compare_exchange(used, used + est, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    break;
                }
            }
        } else {
            self.ram_used.fetch_add(est, Ordering::SeqCst);
        }

        let harness = Arc::new(LoaderHarness::new(
            id.clone(),
            loader,
            self.options.harness.clone(),
        ));
        {
            let mut hs = self.harnesses.lock().unwrap();
            if hs.contains_key(&id) {
                self.ram_used.fetch_sub(est, Ordering::SeqCst);
                bail!("{id}: already managed");
            }
            hs.insert(id.clone(), Arc::clone(&harness));
        }
        self.publish(&id, State::New);

        let this = Arc::clone(self);
        harness.start_loading()?;
        self.publish(&id, State::Loading);
        self.load_pool.execute(move || this.run_load(harness, est));
        Ok(())
    }

    fn run_load(self: &Arc<Self>, harness: Arc<LoaderHarness>, est: u64) {
        let id = harness.id().clone();
        match harness.load() {
            Ok(servable) => {
                self.serving.rcu(|m| {
                    let mut m = m.clone();
                    m.entry(id.name.clone())
                        .or_default()
                        .insert(id.version, servable.clone());
                    m
                });
                self.publish(&id, State::Ready);
                crate::log_info!("{id} ready ({est}B reserved)");
            }
            Err(e) => {
                self.ram_used.fetch_sub(est, Ordering::SeqCst);
                self.publish(&id, State::Error(e.to_string()));
                crate::log_error!("{id} failed to load: {e}");
            }
        }
    }

    /// Synchronous convenience: load and wait until settled.
    pub fn load_and_wait(
        self: &Arc<Self>,
        id: ServableId,
        loader: Arc<dyn Loader>,
        timeout: Duration,
    ) -> Result<()> {
        self.manage_and_load(id.clone(), loader)?;
        match self.monitor.wait_until_settled(&id, timeout) {
            Some(State::Ready) => Ok(()),
            Some(State::Error(e)) => bail!("{id}: {e}"),
            other => bail!("{id}: did not settle ({other:?})"),
        }
    }

    /// §2.1.2 start-up path: load a batch using *all* available threads
    /// (a temporary wide pool), blocking until every load settles.
    pub fn parallel_initial_load(
        self: &Arc<Self>,
        items: Vec<(ServableId, Arc<dyn Loader>)>,
        threads: usize,
    ) -> Vec<(ServableId, Result<()>)> {
        let pool = ThreadPool::new(&format!("{}-init", self.options.name), threads.max(1));
        let wg = WaitGroup::new();
        let results = Arc::new(Mutex::new(Vec::new()));
        for (id, loader) in items {
            // Admission + harness bookkeeping stays on this thread;
            // the load itself fans out over the temporary pool.
            let est = match loader.estimate() {
                Ok(e) => e.ram_bytes,
                Err(e) => {
                    results.lock().unwrap().push((id, Err(e)));
                    continue;
                }
            };
            let harness = Arc::new(LoaderHarness::new(
                id.clone(),
                loader,
                self.options.harness.clone(),
            ));
            {
                let mut hs = self.harnesses.lock().unwrap();
                if hs.contains_key(&id) {
                    results
                        .lock()
                        .unwrap()
                        .push((id.clone(), Err(anyhow!("already managed"))));
                    continue;
                }
                hs.insert(id.clone(), Arc::clone(&harness));
            }
            self.ram_used.fetch_add(est, Ordering::SeqCst);
            if harness.start_loading().is_err() {
                continue;
            }
            self.publish(&id, State::Loading);
            let this = Arc::clone(self);
            let res = Arc::clone(&results);
            let token = wg.token();
            pool.execute(move || {
                this.run_load(Arc::clone(&harness), est);
                let outcome = match harness.state() {
                    State::Ready => Ok(()),
                    State::Error(e) => Err(anyhow!("{e}")),
                    s => Err(anyhow!("unexpected state {s:?}")),
                };
                res.lock().unwrap().push((harness.id().clone(), outcome));
                drop(token);
            });
        }
        wg.wait();
        Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap()
    }

    // ----------------------------------------------------------- unloads

    /// Asynchronously unload `id` on the load pool.
    pub fn unload(self: &Arc<Self>, id: ServableId) -> Result<()> {
        let harness = self
            .harnesses
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow!("{id}: not managed"))?;
        harness.start_unloading()?;
        self.publish(&id, State::Unloading);

        // Remove from the serving map immediately: no new handles.
        let mut removed: Option<ServableBox> = None;
        self.serving.rcu(|m| {
            let mut m = m.clone();
            if let Some(versions) = m.get_mut(&id.name) {
                removed = versions.remove(&id.version);
                if versions.is_empty() {
                    m.remove(&id.name);
                }
            }
            m
        });

        let this = Arc::clone(self);
        self.load_pool.execute(move || {
            let id = harness.id().clone();
            if let Some(servable) = removed {
                harness.loader().unload(&servable);
                let est = harness
                    .loader()
                    .estimate()
                    .map(|e| e.ram_bytes)
                    .unwrap_or(0);
                this.ram_used.fetch_sub(est, Ordering::SeqCst);
                // Final drop (possibly the big free) on the reclaim
                // thread, followed by malloc_trim.
                this.reclaimer.defer_and_trim(servable);
            }
            let _ = harness.done_unloading();
            this.publish(&id, State::Disabled);
            this.harnesses.lock().unwrap().remove(&id);
            crate::log_info!("{id} unloaded");
        });
        Ok(())
    }

    /// Synchronous convenience: unload and wait for `Disabled`.
    pub fn unload_and_wait(self: &Arc<Self>, id: ServableId, timeout: Duration) -> Result<()> {
        self.unload(id.clone())?;
        let deadline = std::time::Instant::now() + timeout;
        while std::time::Instant::now() < deadline {
            if self.monitor.state_of(&id) == Some(State::Disabled) {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        bail!("{id}: unload did not complete in {timeout:?}")
    }

    // ----------------------------------------------------------- lookups

    /// Resolve a typed handle. THE inference hot path: one RCU read, one
    /// map lookup, one Arc clone pair.
    pub fn handle<T: Send + Sync + 'static>(
        &self,
        name: &str,
        version: VersionRequest,
    ) -> Result<ServableHandle<T>> {
        use crate::base::error::ErrorKind;
        let guard = self.serving.read();
        let versions = guard
            .get(name)
            .ok_or_else(|| ErrorKind::NotFound.err(format!("servable '{name}' not found")))?;
        let (v, servable) = match version {
            VersionRequest::Latest => {
                let (v, s) = versions.iter().next_back().ok_or_else(|| {
                    ErrorKind::NotFound.err(format!("servable '{name}' has no ready versions"))
                })?;
                (*v, s)
            }
            VersionRequest::Specific(v) => (
                v,
                versions.get(&v).ok_or_else(|| {
                    ErrorKind::NotFound.err(format!("servable '{name}' version {v} not ready"))
                })?,
            ),
        };
        let id = ServableId::new(name, v);
        ServableHandle::new(id.clone(), Arc::clone(servable), self.reclaimer.clone())
            .map_err(|_| anyhow!("{id}: servable has unexpected type"))
    }

    /// Ready version numbers for `name` (ascending).
    pub fn ready_versions(&self, name: &str) -> Vec<u64> {
        self.serving
            .read()
            .get(name)
            .map(|m| m.keys().copied().collect())
            .unwrap_or_default()
    }

    /// All ready servable ids.
    pub fn all_ready(&self) -> Vec<ServableId> {
        let guard = self.serving.read();
        let mut out: Vec<ServableId> = guard
            .iter()
            .flat_map(|(n, vs)| vs.keys().map(move |v| ServableId::new(n.clone(), *v)))
            .collect();
        out.sort();
        out
    }

    /// Names with at least one ready version.
    pub fn ready_names(&self) -> Vec<String> {
        let guard = self.serving.read();
        let mut names: Vec<String> = guard.keys().cloned().collect();
        names.sort();
        names
    }

    /// Forget a version whose load ended in `Error`, so a retrying
    /// caller (the AVM's load-retry loop) can `manage_and_load` it
    /// again. Only errored harnesses are removable this way — any
    /// other state returns `false` and the harness stays managed, so
    /// this can never be used to wipe a live version's bookkeeping.
    pub fn forget_errored(&self, id: &ServableId) -> bool {
        let mut hs = self.harnesses.lock().unwrap();
        match hs.get(id) {
            Some(h) if matches!(h.state(), State::Error(_)) => {
                hs.remove(id);
                true
            }
            _ => false,
        }
    }

    /// Wait for the load pool to drain (tests/benches).
    pub fn quiesce(&self) {
        self.load_pool.wait_idle();
        self.reclaimer.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::{FnLoader, ResourceEstimate};

    fn mgr() -> Arc<BasicManager> {
        BasicManager::with_defaults()
    }

    fn load_const(m: &Arc<BasicManager>, name: &str, version: u64, value: u32) {
        m.load_and_wait(
            ServableId::new(name, version),
            Arc::new(FnLoader::constant(value)),
            Duration::from_secs(5),
        )
        .unwrap();
    }

    #[test]
    fn load_then_handle() {
        let m = mgr();
        load_const(&m, "m", 1, 41);
        let h = m.handle::<u32>("m", VersionRequest::Latest).unwrap();
        assert_eq!(*h, 41);
        assert_eq!(h.id(), &ServableId::new("m", 1));
    }

    #[test]
    fn latest_prefers_highest_version() {
        let m = mgr();
        load_const(&m, "m", 1, 1);
        load_const(&m, "m", 3, 3);
        load_const(&m, "m", 2, 2);
        let h = m.handle::<u32>("m", VersionRequest::Latest).unwrap();
        assert_eq!(*h, 3);
        let h = m.handle::<u32>("m", VersionRequest::Specific(1)).unwrap();
        assert_eq!(*h, 1);
        assert_eq!(m.ready_versions("m"), vec![1, 2, 3]);
    }

    #[test]
    fn missing_servable_errors() {
        let m = mgr();
        assert!(m.handle::<u32>("nope", VersionRequest::Latest).is_err());
        load_const(&m, "m", 1, 0);
        assert!(m.handle::<u32>("m", VersionRequest::Specific(9)).is_err());
    }

    #[test]
    fn wrong_type_errors() {
        let m = mgr();
        load_const(&m, "m", 1, 7);
        let err = m.handle::<String>("m", VersionRequest::Latest).unwrap_err();
        assert!(err.to_string().contains("unexpected type"));
    }

    #[test]
    fn unload_removes_from_serving() {
        let m = mgr();
        load_const(&m, "m", 1, 1);
        load_const(&m, "m", 2, 2);
        m.unload_and_wait(ServableId::new("m", 1), Duration::from_secs(5)).unwrap();
        assert_eq!(m.ready_versions("m"), vec![2]);
        assert!(m.handle::<u32>("m", VersionRequest::Specific(1)).is_err());
        // version 2 unaffected
        assert_eq!(*m.handle::<u32>("m", VersionRequest::Latest).unwrap(), 2);
    }

    #[test]
    fn handle_keeps_unloaded_servable_alive() {
        let m = mgr();
        load_const(&m, "m", 1, 99);
        let h = m.handle::<u32>("m", VersionRequest::Latest).unwrap();
        m.unload_and_wait(ServableId::new("m", 1), Duration::from_secs(5)).unwrap();
        // The handle still works even though the version is unloaded.
        assert_eq!(*h, 99);
    }

    #[test]
    fn failed_load_reports_error_state() {
        let m = mgr();
        let id = ServableId::new("bad", 1);
        let err = m.load_and_wait(
            id.clone(),
            Arc::new(FnLoader::failing("corrupt artifact")),
            Duration::from_secs(5),
        );
        assert!(err.is_err());
        assert!(matches!(m.monitor().state_of(&id), Some(State::Error(_))));
        assert!(m.ready_versions("bad").is_empty());
    }

    #[test]
    fn ram_admission_control() {
        let m = BasicManager::new(ManagerOptions {
            ram_capacity_bytes: Some(1000),
            ..Default::default()
        });
        let big = |bytes: u64, v: u64| {
            (
                ServableId::new("m", v),
                Arc::new(FnLoader::new(ResourceEstimate::ram(bytes), "blob", || {
                    Ok(Arc::new(0u8) as ServableBox)
                })) as Arc<dyn Loader>,
            )
        };
        let (id1, l1) = big(600, 1);
        m.load_and_wait(id1, l1, Duration::from_secs(5)).unwrap();
        assert_eq!(m.ram_used_bytes(), 600);
        // Second one doesn't fit.
        let (id2, l2) = big(600, 2);
        assert!(m.manage_and_load(id2.clone(), l2).is_err());
        assert!(matches!(m.monitor().state_of(&id2), Some(State::Error(_))));
        // Unload frees budget.
        m.unload_and_wait(ServableId::new("m", 1), Duration::from_secs(5)).unwrap();
        assert_eq!(m.ram_used_bytes(), 0);
        let (id3, l3) = big(900, 3);
        m.load_and_wait(id3, l3, Duration::from_secs(5)).unwrap();
    }

    #[test]
    fn forget_errored_allows_reload() {
        let m = mgr();
        let id = ServableId::new("flaky", 1);
        m.load_and_wait(
            id.clone(),
            Arc::new(FnLoader::failing("transient outage")),
            Duration::from_secs(5),
        )
        .unwrap_err();
        // Errored versions stay managed ("already managed") until
        // explicitly forgotten…
        assert!(m
            .manage_and_load(id.clone(), Arc::new(FnLoader::constant(1u32)))
            .unwrap_err()
            .to_string()
            .contains("already managed"));
        assert!(m.forget_errored(&id));
        // …after which the retry loads cleanly.
        m.load_and_wait(id.clone(), Arc::new(FnLoader::constant(7u32)), Duration::from_secs(5))
            .unwrap();
        assert_eq!(*m.handle::<u32>("flaky", VersionRequest::Latest).unwrap(), 7);
        // A healthy version is NOT forgettable.
        assert!(!m.forget_errored(&id));
        assert_eq!(m.ready_versions("flaky"), vec![1]);
    }

    #[test]
    fn duplicate_manage_rejected() {
        let m = mgr();
        load_const(&m, "m", 1, 1);
        let err = m.manage_and_load(
            ServableId::new("m", 1),
            Arc::new(FnLoader::constant(2u32)),
        );
        assert!(err.unwrap_err().to_string().contains("already managed"));
    }

    #[test]
    fn parallel_initial_load_loads_everything() {
        let m = mgr();
        let items: Vec<(ServableId, Arc<dyn Loader>)> = (0..16)
            .map(|i| {
                (
                    ServableId::new(format!("m{i}"), 1),
                    Arc::new(FnLoader::constant(i as u32)) as Arc<dyn Loader>,
                )
            })
            .collect();
        let results = m.parallel_initial_load(items, 8);
        assert_eq!(results.len(), 16);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        assert_eq!(m.ready_names().len(), 16);
    }

    #[test]
    fn all_ready_sorted() {
        let m = mgr();
        load_const(&m, "b", 2, 0);
        load_const(&m, "a", 1, 0);
        let ids = m.all_ready();
        assert_eq!(ids, vec![ServableId::new("a", 1), ServableId::new("b", 2)]);
    }
}
