//! Source Adapters: transform version payloads along the chain (§2.1).
//!
//! An adapter is an [`AspiredVersionsCallback<From>`] that converts each
//! payload to `To` and forwards to a downstream callback. Adapters
//! compose (the paper: "chains of multiple Source Adapters"); the
//! platform-specific adapters that turn storage paths into `Loader`s
//! live with their runtimes ([`crate::runtime::hlo_servable`] for the
//! HLO platform, [`crate::inference::table`] for "BananaFlow" tables).

use crate::base::aspired::{AspiredVersionsCallback, ServableData};
use std::sync::{Arc, Mutex};

/// Adapter built from a conversion function.
pub struct FnSourceAdapter<From, To> {
    convert: Box<dyn Fn(&ServableData<From>) -> anyhow::Result<To> + Send + Sync>,
    downstream: Mutex<Option<Arc<dyn AspiredVersionsCallback<To>>>>,
}

impl<From: Send + 'static, To: Send + 'static> FnSourceAdapter<From, To> {
    pub fn new<F>(convert: F) -> Arc<Self>
    where
        F: Fn(&ServableData<From>) -> anyhow::Result<To> + Send + Sync + 'static,
    {
        Arc::new(FnSourceAdapter {
            convert: Box::new(convert),
            downstream: Mutex::new(None),
        })
    }

    /// Connect the downstream callback (manager, router or next adapter).
    pub fn connect(&self, downstream: Arc<dyn AspiredVersionsCallback<To>>) {
        *self.downstream.lock().unwrap() = Some(downstream);
    }
}

impl<From: Send + 'static, To: Send + 'static> AspiredVersionsCallback<From>
    for FnSourceAdapter<From, To>
{
    fn set_aspired_versions(&self, servable_name: &str, versions: Vec<ServableData<From>>) {
        let downstream = match self.downstream.lock().unwrap().clone() {
            Some(d) => d,
            None => return,
        };
        let converted = versions
            .into_iter()
            .map(|data| match &data.payload {
                // Conversion errors become errored versions so the
                // manager can surface them (§2.1 error flow).
                Ok(_) => match (self.convert)(&data) {
                    Ok(to) => ServableData::ok(data.id, to),
                    Err(e) => ServableData::err(data.id, e),
                },
                Err(_) => ServableData::err(
                    data.id.clone(),
                    anyhow::anyhow!("upstream error for {}", data.id),
                ),
            })
            .collect();
        downstream.set_aspired_versions(servable_name, converted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::aspired::RecordingCallback;
    use crate::base::servable::ServableId;

    #[test]
    fn converts_payloads() {
        let adapter = FnSourceAdapter::<u32, String>::new(|d| {
            Ok(format!("v{}", d.payload.as_ref().unwrap()))
        });
        let sink = RecordingCallback::<String>::new();
        adapter.connect(sink.clone());
        adapter.set_aspired_versions(
            "m",
            vec![ServableData::ok(ServableId::new("m", 1), 42u32)],
        );
        let calls = sink.calls.lock().unwrap();
        assert_eq!(calls[0].1[0].payload.as_ref().unwrap(), "v42");
    }

    #[test]
    fn conversion_error_becomes_errored_version() {
        let adapter =
            FnSourceAdapter::<u32, String>::new(|_| anyhow::bail!("cannot convert"));
        let sink = RecordingCallback::<String>::new();
        adapter.connect(sink.clone());
        adapter.set_aspired_versions(
            "m",
            vec![ServableData::ok(ServableId::new("m", 1), 1u32)],
        );
        let calls = sink.calls.lock().unwrap();
        assert!(calls[0].1[0].payload.is_err());
    }

    #[test]
    fn adapters_chain() {
        // path-ish -> length -> string, two adapters deep.
        let a2 = FnSourceAdapter::<usize, String>::new(|d| {
            Ok(format!("len={}", d.payload.as_ref().unwrap()))
        });
        let a1 = FnSourceAdapter::<String, usize>::new(|d| {
            Ok(d.payload.as_ref().unwrap().len())
        });
        let sink = RecordingCallback::<String>::new();
        a2.connect(sink.clone());
        a1.connect(a2);
        a1.set_aspired_versions(
            "m",
            vec![ServableData::ok(ServableId::new("m", 3), "abcd".to_string())],
        );
        let calls = sink.calls.lock().unwrap();
        assert_eq!(calls[0].1[0].payload.as_ref().unwrap(), "len=4");
        assert_eq!(calls[0].1[0].id, ServableId::new("m", 3));
    }

    #[test]
    fn unconnected_adapter_drops_silently() {
        let adapter = FnSourceAdapter::<u32, u32>::new(|d| Ok(*d.payload.as_ref().unwrap()));
        // No downstream: must not panic.
        adapter.set_aspired_versions(
            "m",
            vec![ServableData::ok(ServableId::new("m", 1), 1u32)],
        );
    }
}
