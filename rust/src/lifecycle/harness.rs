//! Per-version state machine: `New → Loading → Ready → Unloading →
//! Disabled`, with error states and bounded load retries.
//!
//! Mirrors TF-Serving's `LoaderHarness`: the manager's bookkeeping for
//! one (servable, version) as it moves through its life.

use crate::base::loader::Loader;
use crate::base::servable::{ServableBox, ServableId};
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// Lifecycle states of one servable version.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum State {
    /// Known but not requested to load yet.
    New,
    /// Load in progress on the load pool.
    Loading,
    /// Serving traffic.
    Ready,
    /// Unload in progress.
    Unloading,
    /// Fully unloaded; terminal.
    Disabled,
    /// Load failed (after retries); terminal.
    Error(String),
}

impl State {
    pub fn is_terminal(&self) -> bool {
        matches!(self, State::Disabled | State::Error(_))
    }

    /// Short label for events/metrics.
    pub fn label(&self) -> &'static str {
        match self {
            State::New => "new",
            State::Loading => "loading",
            State::Ready => "ready",
            State::Unloading => "unloading",
            State::Disabled => "disabled",
            State::Error(_) => "error",
        }
    }

    /// Label plus the failure reason for `Error` — what
    /// `GetModelMetadata`/`GetModelStatus` surface so a failed load is
    /// diagnosable from the client side, not just "error".
    pub fn describe(&self) -> String {
        match self {
            State::Error(reason) => format!("error: {reason}"),
            other => other.label().to_string(),
        }
    }
}

/// Options controlling harness behaviour.
#[derive(Debug, Clone)]
pub struct HarnessOptions {
    /// Times a failed load is retried before entering `Error`.
    pub max_load_retries: u32,
}

impl Default for HarnessOptions {
    fn default() -> Self {
        HarnessOptions { max_load_retries: 1 }
    }
}

/// Bookkeeping for one (servable, version).
pub struct LoaderHarness {
    id: ServableId,
    loader: Arc<dyn Loader>,
    state: Mutex<State>,
    options: HarnessOptions,
}

impl LoaderHarness {
    pub fn new(id: ServableId, loader: Arc<dyn Loader>, options: HarnessOptions) -> Self {
        LoaderHarness { id, loader, state: Mutex::new(State::New), options }
    }

    pub fn id(&self) -> &ServableId {
        &self.id
    }

    pub fn loader(&self) -> &Arc<dyn Loader> {
        &self.loader
    }

    pub fn state(&self) -> State {
        self.state.lock().unwrap().clone()
    }

    fn transition(&self, from: &[State], to: State) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        if !from.contains(&s) {
            bail!("{}: illegal transition {s:?} -> {to:?}", self.id);
        }
        *s = to;
        Ok(())
    }

    /// Mark load started. `New → Loading`.
    pub fn start_loading(&self) -> Result<()> {
        self.transition(&[State::New], State::Loading)
    }

    /// Execute the load with retries. `Loading → Ready | Error`.
    /// Returns the servable on success.
    pub fn load(&self) -> Result<ServableBox> {
        {
            let s = self.state.lock().unwrap();
            if *s != State::Loading {
                bail!("{}: load() in state {s:?}", self.id);
            }
        }
        let mut last_err = None;
        for attempt in 0..=self.options.max_load_retries {
            match self.loader.load() {
                Ok(servable) => {
                    self.transition(&[State::Loading], State::Ready)?;
                    if attempt > 0 {
                        crate::log_info!("{} loaded after {attempt} retries", self.id);
                    }
                    return Ok(servable);
                }
                Err(e) => {
                    crate::log_warn!(
                        "{} load attempt {attempt} failed: {e}",
                        self.id
                    );
                    last_err = Some(e);
                }
            }
        }
        let msg = last_err.unwrap().to_string();
        let _ = self.transition(&[State::Loading], State::Error(msg.clone()));
        bail!("{}: load failed: {msg}", self.id);
    }

    /// Mark unload started. `Ready → Unloading`.
    pub fn start_unloading(&self) -> Result<()> {
        self.transition(&[State::Ready], State::Unloading)
    }

    /// Mark unload complete. `Unloading → Disabled`.
    pub fn done_unloading(&self) -> Result<()> {
        self.transition(&[State::Unloading], State::Disabled)
    }

    /// Cancel before any load started. `New → Disabled`.
    pub fn cancel(&self) -> Result<()> {
        self.transition(&[State::New], State::Disabled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::FnLoader;
    use std::sync::atomic::{AtomicU32, Ordering};

    fn harness(loader: FnLoader) -> LoaderHarness {
        LoaderHarness::new(
            ServableId::new("m", 1),
            Arc::new(loader),
            HarnessOptions::default(),
        )
    }

    #[test]
    fn happy_path() {
        let h = harness(FnLoader::constant(5u8));
        assert_eq!(h.state(), State::New);
        h.start_loading().unwrap();
        assert_eq!(h.state(), State::Loading);
        let s = h.load().unwrap();
        assert_eq!(*s.downcast::<u8>().unwrap(), 5);
        assert_eq!(h.state(), State::Ready);
        h.start_unloading().unwrap();
        h.done_unloading().unwrap();
        assert_eq!(h.state(), State::Disabled);
        assert!(h.state().is_terminal());
    }

    #[test]
    fn load_failure_goes_to_error() {
        let h = harness(FnLoader::failing("disk gone"));
        h.start_loading().unwrap();
        assert!(h.load().is_err());
        match h.state() {
            State::Error(msg) => assert!(msg.contains("disk gone")),
            s => panic!("expected error, got {s:?}"),
        }
    }

    #[test]
    fn load_retries_then_succeeds() {
        let attempts = Arc::new(AtomicU32::new(0));
        let a = Arc::clone(&attempts);
        let loader = FnLoader::new(
            crate::base::loader::ResourceEstimate::default(),
            "flaky",
            move || {
                if a.fetch_add(1, Ordering::SeqCst) == 0 {
                    anyhow::bail!("transient");
                }
                Ok(Arc::new(1u8) as ServableBox)
            },
        );
        let h = LoaderHarness::new(
            ServableId::new("m", 1),
            Arc::new(loader),
            HarnessOptions { max_load_retries: 2 },
        );
        h.start_loading().unwrap();
        assert!(h.load().is_ok());
        assert_eq!(attempts.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let h = harness(FnLoader::constant(0u8));
        assert!(h.start_unloading().is_err()); // New -> Unloading
        assert!(h.done_unloading().is_err());
        h.start_loading().unwrap();
        assert!(h.start_loading().is_err()); // Loading -> Loading
        assert!(h.cancel().is_err()); // cancel only from New
    }

    #[test]
    fn cancel_from_new() {
        let h = harness(FnLoader::constant(0u8));
        h.cancel().unwrap();
        assert_eq!(h.state(), State::Disabled);
    }

    #[test]
    fn state_labels() {
        assert_eq!(State::Ready.label(), "ready");
        assert_eq!(State::Error("x".into()).label(), "error");
        // describe() keeps the failure reason; labels stay terse.
        assert_eq!(State::Ready.describe(), "ready");
        assert_eq!(State::Error("disk gone".into()).describe(), "error: disk gone");
    }
}
