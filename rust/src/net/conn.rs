//! Per-connection protocol state machines for the reactor.
//!
//! The reactor owns sockets and buffers; a [`ConnProtocol`] owns only
//! the *parse state* of its connection. On every read the reactor
//! appends bytes to the connection's receive buffer and calls
//! [`ConnProtocol::advance`], which consumes complete requests from
//! the front of the buffer and tells the reactor what to do next:
//! wait for more bytes, write interim bytes (HTTP `100 Continue`),
//! answer a protocol error directly, or hand a ready request to the
//! worker pool as a [`Step::Dispatch`] closure. The closure runs the
//! real handler off the reactor thread and returns the fully encoded
//! reply bytes, so the reactor only ever does `read`/`write`.
//!
//! Both machines resume cleanly from arbitrary byte boundaries
//! (partial frame headers, a request line split mid-token, chunked
//! bodies trickling in) — that is the whole point of the reactor:
//! slow peers cost a buffer, not a thread.

use crate::http::server::{
    body_framing, read_head, render_response, wants_keep_alive, BodyFraming, BodySink,
    HttpHandler, HttpRequest, HttpResponse, SinkFactory, MAX_HEADERS, MAX_HEADER_LINE,
    MAX_REQUEST_LINE,
};
use crate::rpc::frame::{HEADER, MAX_FRAME};
use crate::rpc::proto::{Request, Response};
use crate::rpc::server::Handler;
use std::io::Cursor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Encoded reply bytes plus the close decision for after the flush.
pub struct Reply {
    pub bytes: Vec<u8>,
    pub close: bool,
}

/// What the reactor should do after one `advance` call.
pub enum Step {
    /// Incomplete request: wait for more bytes.
    NeedMore,
    /// Write these bytes now and keep parsing (HTTP `100 Continue`).
    Interim(Vec<u8>),
    /// A complete request: run this on a worker; it returns the reply.
    Dispatch(Box<dyn FnOnce() -> Reply + Send>),
    /// Protocol-level reply produced without dispatching (parse
    /// errors, limit violations).
    Reply(Reply),
    /// Drop the connection without writing anything.
    Close,
}

/// Protocol state machine; one per live connection.
pub trait ConnProtocol: Send {
    /// Consume what's consumable from the front of `rbuf`; the
    /// reactor calls this after reads, after flushes, and again after
    /// every non-`NeedMore` step (pipelined requests).
    fn advance(&mut self, rbuf: &mut Vec<u8>) -> Step;
}

/// How a listener builds per-connection machines, plus the canned
/// bytes an over-`max_connections` connect is answered with.
pub struct ProtocolFactory {
    /// Metrics/log label: "rpc" or "http".
    pub label: &'static str,
    pub make: Box<dyn Fn() -> Box<dyn ConnProtocol> + Send + Sync>,
    /// Written (best effort, once) to a rejected connection before it
    /// is dropped: a framed `Unavailable` / an HTTP 503.
    pub reject: Vec<u8>,
}

// ------------------------------------------------------------- RPC

/// Length-prefixed RPC framing: `[u32 le len][payload]`.
pub struct RpcProto {
    handler: Handler,
    served: Arc<AtomicU64>,
}

impl RpcProto {
    pub fn new(handler: Handler, served: Arc<AtomicU64>) -> RpcProto {
        RpcProto { handler, served }
    }
}

/// Encode a response with its frame header already patched (the
/// reactor writes buffers as-is; there is no later `write_framed` to
/// fix the length up).
fn framed(resp: &Response) -> Vec<u8> {
    let mut out = Vec::new();
    resp.encode_framed_into(&mut out);
    let payload = (out.len() - HEADER) as u32;
    out[..HEADER].copy_from_slice(&payload.to_le_bytes());
    out
}

/// The canned over-limit reply for RPC listeners: a retryable
/// `Unavailable`, mirroring admission-control shedding.
pub fn rpc_reject_bytes() -> Vec<u8> {
    framed(&Response::Error {
        kind: crate::base::error::ErrorKind::Unavailable,
        message: "connection limit reached, retry against another replica".into(),
    })
}

impl ConnProtocol for RpcProto {
    fn advance(&mut self, rbuf: &mut Vec<u8>) -> Step {
        if rbuf.len() < HEADER {
            return Step::NeedMore;
        }
        let len = u32::from_le_bytes([rbuf[0], rbuf[1], rbuf[2], rbuf[3]]) as usize;
        if len > MAX_FRAME {
            // The legacy loop just hung up; answering first tells the
            // peer *why* before the close.
            return Step::Reply(Reply {
                bytes: framed(&Response::Error {
                    kind: crate::base::error::ErrorKind::InvalidArgument,
                    message: format!("incoming frame too large: {len} bytes"),
                }),
                close: true,
            });
        }
        if rbuf.len() < HEADER + len {
            return Step::NeedMore;
        }
        let payload = rbuf[HEADER..HEADER + len].to_vec();
        rbuf.drain(..HEADER + len);
        let handler = Arc::clone(&self.handler);
        let served = Arc::clone(&self.served);
        Step::Dispatch(Box::new(move || {
            let response = match Request::decode(&payload) {
                Ok(req) => handler(req),
                Err(e) => Response::Error {
                    kind: crate::base::error::ErrorKind::InvalidArgument,
                    message: format!("bad request: {e}"),
                },
            };
            served.fetch_add(1, Ordering::Relaxed);
            let bytes = framed(&response);
            // Sole-owner output tensors go back to the pool once their
            // bytes are serialized — same contract as the legacy loop.
            response.recycle_buffers();
            Reply { bytes, close: false }
        }))
    }
}

// ------------------------------------------------------------ HTTP

/// Head bytes the buffer may accumulate before we give up with a 431:
/// the request line plus every header at its individual cap.
const MAX_HEAD: usize = MAX_REQUEST_LINE + (MAX_HEADERS + 1) * (MAX_HEADER_LINE + 2) + 4;
/// Cap on a chunk-size line (hex digits + extensions), matching the
/// legacy reader's limit.
const MAX_CHUNK_LINE: usize = 1024;

/// HTTP/1.1 keep-alive parsing, one request in flight at a time.
pub struct HttpProto {
    handler: HttpHandler,
    served: Arc<AtomicU64>,
    /// When set, request heads this factory claims stream their body
    /// bytes into a [`BodySink`] as they arrive instead of buffering.
    sinks: Option<SinkFactory>,
    state: HttpState,
}

enum HttpState {
    /// Accumulating request line + headers.
    Head,
    /// Head parsed; accumulating (or streaming) the body.
    Body {
        req: HttpRequest,
        framing: BodyState,
        /// Streaming decoder for this request, if a sink claimed it.
        sink: Option<Box<dyn BodySink>>,
        keep_alive: bool,
        sent_continue: bool,
        expects_continue: bool,
    },
}

enum BodyState {
    /// Bytes still missing (counts down on the streaming path).
    Length(usize),
    Chunked(ChunkMachine),
}

impl HttpProto {
    pub fn new(handler: HttpHandler, served: Arc<AtomicU64>) -> HttpProto {
        Self::new_with(handler, served, None)
    }

    pub fn new_with(
        handler: HttpHandler,
        served: Arc<AtomicU64>,
        sinks: Option<SinkFactory>,
    ) -> HttpProto {
        HttpProto { handler, served, sinks, state: HttpState::Head }
    }

    fn dispatch(&mut self, mut req: HttpRequest, body: Vec<u8>, keep_alive: bool) -> Step {
        req.body = body;
        let handler = Arc::clone(&self.handler);
        let served = Arc::clone(&self.served);
        Step::Dispatch(Box::new(move || {
            let resp = handler(&req);
            served.fetch_add(1, Ordering::Relaxed);
            let mut bytes = Vec::new();
            render_response(&mut bytes, &resp, keep_alive);
            Reply { bytes, close: !keep_alive }
        }))
    }

    /// Streamed-body completion: the sink already holds every body
    /// byte; its `finish` runs on a worker like a handler would.
    fn dispatch_sink(
        &mut self,
        req: HttpRequest,
        sink: Box<dyn BodySink>,
        keep_alive: bool,
    ) -> Step {
        let served = Arc::clone(&self.served);
        Step::Dispatch(Box::new(move || {
            let resp = sink.finish(&req);
            served.fetch_add(1, Ordering::Relaxed);
            let mut bytes = Vec::new();
            render_response(&mut bytes, &resp, keep_alive);
            Reply { bytes, close: !keep_alive }
        }))
    }
}

/// Render an error response; HTTP parse errors always close (the
/// byte stream is no longer in a known state).
fn http_error(status: u16, message: &str) -> Step {
    let resp = HttpResponse::error(status, message);
    let mut bytes = Vec::new();
    render_response(&mut bytes, &resp, false);
    Step::Reply(Reply { bytes, close: true })
}

/// Index one past the head terminator (`\r\n\r\n`, tolerating bare-LF
/// line endings the line parser also accepts), or `None`.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            match buf.get(i + 1) {
                Some(b'\n') => return Some(i + 2),
                Some(b'\r') if buf.get(i + 2) == Some(&b'\n') => return Some(i + 3),
                _ => {}
            }
        }
        i += 1;
    }
    None
}

impl ConnProtocol for HttpProto {
    fn advance(&mut self, rbuf: &mut Vec<u8>) -> Step {
        loop {
            match &mut self.state {
                HttpState::Head => {
                    let Some(end) = find_head_end(rbuf) else {
                        if rbuf.len() > MAX_HEAD {
                            return http_error(431, "request head too large");
                        }
                        return Step::NeedMore;
                    };
                    let mut cursor = Cursor::new(&rbuf[..end]);
                    let parsed = read_head(&mut cursor);
                    let consumed = cursor.position() as usize;
                    rbuf.drain(..consumed.max(end).min(rbuf.len()));
                    let req = match parsed {
                        Ok(Some(req)) => req,
                        // Only stray blank lines before a request
                        // started (RFC 9112 §2.2): keep waiting.
                        Ok(None) => continue,
                        Err(e) => return http_error(e.status, &e.message),
                    };
                    let keep_alive = wants_keep_alive(&req);
                    let framing = match body_framing(&req) {
                        Ok(f) => f,
                        Err(e) => return http_error(e.status, &e.message),
                    };
                    let expects_continue = req
                        .header("expect")
                        .map(|v| v.eq_ignore_ascii_case("100-continue"))
                        .unwrap_or(false);
                    // Give a sink factory first claim on the body.
                    let sink = self.sinks.as_ref().and_then(|f| f(&req));
                    match framing {
                        BodyFraming::Empty => {
                            return match sink {
                                Some(sink) => self.dispatch_sink(req, sink, keep_alive),
                                None => self.dispatch(req, Vec::new(), keep_alive),
                            };
                        }
                        BodyFraming::Length(n) => {
                            self.state = HttpState::Body {
                                req,
                                framing: BodyState::Length(n),
                                sink,
                                keep_alive,
                                sent_continue: false,
                                expects_continue,
                            };
                        }
                        BodyFraming::Chunked => {
                            self.state = HttpState::Body {
                                req,
                                framing: BodyState::Chunked(ChunkMachine::new()),
                                sink,
                                keep_alive,
                                sent_continue: false,
                                expects_continue,
                            };
                        }
                    }
                }
                HttpState::Body { framing, sink, sent_continue, expects_continue, .. } => {
                    // The framing checks passed, so a waiting client
                    // may be told to send its body (RFC 9110 §10.1.1).
                    if *expects_continue && !*sent_continue {
                        *sent_continue = true;
                        return Step::Interim(b"HTTP/1.1 100 Continue\r\n\r\n".to_vec());
                    }
                    // `Some(bytes)` = buffered body complete;
                    // `None` = streamed into the sink, complete.
                    let body = match framing {
                        BodyState::Length(n) => {
                            if let Some(sink) = sink {
                                // Stream what's here; wait for the rest.
                                let take = rbuf.len().min(*n);
                                if take > 0 {
                                    sink.feed(&rbuf[..take]);
                                    rbuf.drain(..take);
                                    *n -= take;
                                }
                                if *n > 0 {
                                    return Step::NeedMore;
                                }
                                None
                            } else {
                                if rbuf.len() < *n {
                                    return Step::NeedMore;
                                }
                                let body = rbuf[..*n].to_vec();
                                rbuf.drain(..*n);
                                Some(body)
                            }
                        }
                        BodyState::Chunked(machine) => {
                            let complete = match machine.feed(rbuf) {
                                Ok(c) => c,
                                Err((status, msg)) => return http_error(status, &msg),
                            };
                            if let Some(sink) = sink {
                                // Drain decoded chunk data into the sink
                                // as it arrives (the machine's
                                // cumulative cap still applies).
                                if !machine.body.is_empty() {
                                    sink.feed(&machine.body);
                                    machine.body.clear();
                                }
                                if !complete {
                                    return Step::NeedMore;
                                }
                                None
                            } else {
                                if !complete {
                                    return Step::NeedMore;
                                }
                                Some(std::mem::take(&mut machine.body))
                            }
                        }
                    };
                    let HttpState::Body { req, sink, keep_alive, .. } =
                        std::mem::replace(&mut self.state, HttpState::Head)
                    else {
                        unreachable!()
                    };
                    return match (body, sink) {
                        (Some(body), _) => self.dispatch(req, body, keep_alive),
                        (None, Some(sink)) => self.dispatch_sink(req, sink, keep_alive),
                        (None, None) => unreachable!("streamed completion without a sink"),
                    };
                }
            }
        }
    }
}

/// Incremental chunked-transfer decoder. Consumes decoded bytes from
/// the front of the receive buffer as they arrive, so a trickling
/// upload is O(bytes), never a per-read reparse.
struct ChunkMachine {
    body: Vec<u8>,
    /// Cumulative declared chunk bytes — the `MAX_BODY` cap must hold
    /// even when the streaming path drains `body` between reads.
    total: usize,
    phase: ChunkPhase,
}

enum ChunkPhase {
    /// Expecting a `SIZE[;ext]\r\n` line.
    Size,
    /// Copying `remaining` data bytes into `body`.
    Data { remaining: usize },
    /// Expecting the `\r\n` after a chunk's data.
    DataCrlf,
    /// Expecting (ignored) trailer lines until the blank line.
    Trailers,
}

/// Pop one `\n`-terminated line (CRLF stripped) off the front of
/// `buf`. `Err(())` = no complete line yet.
fn take_line(buf: &mut Vec<u8>, cap: usize) -> Result<Option<String>, ()> {
    match buf.iter().position(|&b| b == b'\n') {
        Some(nl) => {
            let mut line: Vec<u8> = buf.drain(..nl + 1).collect();
            line.pop();
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            if line.len() > cap {
                return Ok(None); // caller maps to an error
            }
            Ok(Some(String::from_utf8_lossy(&line).into_owned()))
        }
        None if buf.len() > cap + 2 => Ok(None),
        None => Err(()),
    }
}

impl ChunkMachine {
    fn new() -> ChunkMachine {
        ChunkMachine { body: Vec::new(), total: 0, phase: ChunkPhase::Size }
    }

    /// Consume what's available. `Ok(true)` = body complete (in
    /// `self.body`); `Ok(false)` = need more bytes.
    fn feed(&mut self, rbuf: &mut Vec<u8>) -> Result<bool, (u16, String)> {
        loop {
            match &mut self.phase {
                ChunkPhase::Size => {
                    let line = match take_line(rbuf, MAX_CHUNK_LINE) {
                        Err(()) => return Ok(false),
                        Ok(None) => {
                            return Err((431, format!("chunk-size line exceeds {MAX_CHUNK_LINE} bytes")))
                        }
                        Ok(Some(l)) => l,
                    };
                    // Chunk extensions after ';' are allowed, ignored.
                    let size_str = line.split(';').next().unwrap_or("").trim();
                    let size = usize::from_str_radix(size_str, 16)
                        .map_err(|_| (400, format!("bad chunk size {size_str:?}")))?;
                    if self.total.saturating_add(size) > crate::http::server::MAX_BODY {
                        return Err((
                            413,
                            format!("chunked body exceeds {} bytes", crate::http::server::MAX_BODY),
                        ));
                    }
                    self.total = self.total.saturating_add(size);
                    self.phase = if size == 0 {
                        ChunkPhase::Trailers
                    } else {
                        ChunkPhase::Data { remaining: size }
                    };
                }
                ChunkPhase::Data { remaining } => {
                    if rbuf.is_empty() {
                        return Ok(false);
                    }
                    let take = (*remaining).min(rbuf.len());
                    self.body.extend_from_slice(&rbuf[..take]);
                    rbuf.drain(..take);
                    *remaining -= take;
                    if *remaining == 0 {
                        self.phase = ChunkPhase::DataCrlf;
                    }
                }
                ChunkPhase::DataCrlf => {
                    if rbuf.len() < 2 {
                        return Ok(false);
                    }
                    if &rbuf[..2] != b"\r\n" {
                        return Err((400, "chunk missing CRLF terminator".into()));
                    }
                    rbuf.drain(..2);
                    self.phase = ChunkPhase::Size;
                }
                ChunkPhase::Trailers => {
                    let line = match take_line(rbuf, MAX_HEADER_LINE) {
                        Err(()) => return Ok(false),
                        Ok(None) => {
                            return Err((431, format!("trailer line exceeds {MAX_HEADER_LINE} bytes")))
                        }
                        Ok(Some(l)) => l,
                    };
                    if line.is_empty() {
                        return Ok(true);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::error::ErrorKind;

    fn rpc_proto(counter: &Arc<AtomicU64>) -> RpcProto {
        RpcProto::new(
            Arc::new(|req| match req {
                Request::Ping => Response::Pong,
                _ => Response::Error { kind: ErrorKind::Internal, message: "unsupported".into() },
            }),
            Arc::clone(counter),
        )
    }

    fn run(step: Step) -> Reply {
        match step {
            Step::Dispatch(f) => f(),
            _ => panic!("expected a dispatch"),
        }
    }

    #[test]
    fn rpc_frame_resumes_across_partial_reads() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = rpc_proto(&served);
        let mut frame = Vec::new();
        Request::Ping.encode_framed_into(&mut frame);
        let len = (frame.len() - HEADER) as u32;
        frame[..HEADER].copy_from_slice(&len.to_le_bytes());

        let mut rbuf = Vec::new();
        // Byte-at-a-time delivery: NeedMore until the frame completes.
        for (i, b) in frame.iter().enumerate() {
            rbuf.push(*b);
            if i + 1 < frame.len() {
                assert!(matches!(p.advance(&mut rbuf), Step::NeedMore));
            }
        }
        let reply = run(p.advance(&mut rbuf));
        assert!(!reply.close);
        let resp = Response::decode(&reply.bytes[HEADER..]).unwrap();
        assert_eq!(resp, Response::Pong);
        assert!(rbuf.is_empty());
        assert_eq!(served.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn rpc_pipelined_frames_dispatch_back_to_back() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = rpc_proto(&served);
        let mut one = Vec::new();
        Request::Ping.encode_framed_into(&mut one);
        let len = (one.len() - HEADER) as u32;
        one[..HEADER].copy_from_slice(&len.to_le_bytes());
        let mut rbuf = [one.clone(), one].concat();
        for _ in 0..2 {
            let reply = run(p.advance(&mut rbuf));
            assert_eq!(Response::decode(&reply.bytes[HEADER..]).unwrap(), Response::Pong);
        }
        assert!(rbuf.is_empty());
        assert!(matches!(p.advance(&mut rbuf), Step::NeedMore));
    }

    #[test]
    fn rpc_oversized_frame_answered_then_closed() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = rpc_proto(&served);
        let mut rbuf = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        match p.advance(&mut rbuf) {
            Step::Reply(r) => {
                assert!(r.close);
                let resp = Response::decode(&r.bytes[HEADER..]).unwrap();
                assert!(matches!(resp, Response::Error { kind: ErrorKind::InvalidArgument, .. }));
            }
            _ => panic!("expected an error reply"),
        }
    }

    fn http_proto(served: &Arc<AtomicU64>) -> HttpProto {
        HttpProto::new(
            Arc::new(|req: &HttpRequest| {
                HttpResponse::text(200, &format!("{} {} {}", req.method, req.path, req.body.len()))
            }),
            Arc::clone(served),
        )
    }

    fn reply_text(reply: &Reply) -> String {
        String::from_utf8_lossy(&reply.bytes).into_owned()
    }

    #[test]
    fn http_request_split_at_arbitrary_points() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = http_proto(&served);
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        let mut rbuf = Vec::new();
        let mut got = None;
        for b in raw.iter() {
            rbuf.push(*b);
            match p.advance(&mut rbuf) {
                Step::NeedMore => {}
                Step::Dispatch(f) => {
                    got = Some(f());
                    break;
                }
                _ => panic!("unexpected step"),
            }
        }
        let reply = got.expect("request never dispatched");
        let text = reply_text(&reply);
        assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
        assert!(text.ends_with("POST /x 5"), "{text}");
        assert!(!reply.close); // HTTP/1.1 defaults to keep-alive
    }

    #[test]
    fn http_pipelined_keepalive_requests() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = http_proto(&served);
        let mut rbuf = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n".to_vec();
        let first = run(p.advance(&mut rbuf));
        assert!(reply_text(&first).ends_with("GET /a 0"));
        assert!(!first.close);
        let second = run(p.advance(&mut rbuf));
        assert!(reply_text(&second).ends_with("GET /b 0"));
        assert!(second.close, "Connection: close must close after the reply");
        assert!(rbuf.is_empty());
    }

    #[test]
    fn http_chunked_body_trickles_in() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = http_proto(&served);
        let raw = b"POST /c HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n\
                    4\r\nwiki\r\n5;ext=1\r\npedia\r\n0\r\n\r\n";
        let mut rbuf = Vec::new();
        let mut got = None;
        for chunk in raw.chunks(3) {
            rbuf.extend_from_slice(chunk);
            match p.advance(&mut rbuf) {
                Step::NeedMore => {}
                Step::Dispatch(f) => {
                    got = Some(f());
                    break;
                }
                _ => panic!("unexpected step"),
            }
        }
        let text = reply_text(&got.expect("chunked request never dispatched"));
        assert!(text.ends_with("POST /c 9"), "{text}");
    }

    #[test]
    fn http_expect_continue_emits_interim_once() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = http_proto(&served);
        let mut rbuf =
            b"POST /u HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n".to_vec();
        match p.advance(&mut rbuf) {
            Step::Interim(bytes) => {
                assert_eq!(&bytes, b"HTTP/1.1 100 Continue\r\n\r\n");
            }
            _ => panic!("expected interim 100"),
        }
        assert!(matches!(p.advance(&mut rbuf), Step::NeedMore));
        rbuf.extend_from_slice(b"ok");
        let reply = run(p.advance(&mut rbuf));
        assert!(reply_text(&reply).ends_with("POST /u 2"));
    }

    #[test]
    fn http_errors_reply_and_close() {
        // Malformed request line.
        let served = Arc::new(AtomicU64::new(0));
        let mut p = http_proto(&served);
        let mut rbuf = b"NOT-HTTP\r\n\r\n".to_vec();
        match p.advance(&mut rbuf) {
            Step::Reply(r) => {
                assert!(r.close);
                assert!(reply_text(&r).starts_with("HTTP/1.1 400"), "{}", reply_text(&r));
            }
            _ => panic!("expected 400"),
        }
        // Ambiguous framing (smuggling precondition).
        let mut p = http_proto(&served);
        let mut rbuf =
            b"POST / HTTP/1.1\r\nContent-Length: 4\r\nTransfer-Encoding: chunked\r\n\r\n".to_vec();
        match p.advance(&mut rbuf) {
            Step::Reply(r) => assert!(reply_text(&r).starts_with("HTTP/1.1 400")),
            _ => panic!("expected 400"),
        }
        // Oversized head without a terminator.
        let mut p = http_proto(&served);
        let mut rbuf = vec![b'a'; MAX_HEAD + 1];
        match p.advance(&mut rbuf) {
            Step::Reply(r) => assert!(reply_text(&r).starts_with("HTTP/1.1 431")),
            _ => panic!("expected 431"),
        }
        // Oversized declared body.
        let mut p = http_proto(&served);
        let mut rbuf = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            crate::http::server::MAX_BODY + 1
        )
        .into_bytes();
        match p.advance(&mut rbuf) {
            Step::Reply(r) => assert!(reply_text(&r).starts_with("HTTP/1.1 413")),
            _ => panic!("expected 413"),
        }
    }

    #[test]
    fn http_stray_crlf_between_requests_tolerated() {
        let served = Arc::new(AtomicU64::new(0));
        let mut p = http_proto(&served);
        let mut rbuf = b"\r\n\r\nGET /ok HTTP/1.1\r\n\r\n".to_vec();
        let reply = run(p.advance(&mut rbuf));
        assert!(reply_text(&reply).ends_with("GET /ok 0"));
    }

    #[test]
    fn reject_bytes_decode_as_unavailable() {
        let bytes = rpc_reject_bytes();
        let resp = Response::decode(&bytes[HEADER..]).unwrap();
        match resp {
            Response::Error { kind, message } => {
                assert_eq!(kind, ErrorKind::Unavailable);
                assert!(message.contains("connection limit"), "{message}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
