//! Connection tracking for the legacy threaded listeners.
//!
//! The original accept loops spawned one detached thread per
//! connection: `stop()` closed the listener but left every live
//! connection thread (and its socket) stranded until the 60s read
//! timeout fired. The reactor path fixes this structurally (every
//! connection lives in a slab the reactor closes on stop); this
//! tracker fixes the threaded path that remains behind
//! `net.mode = "threaded"`: each connection registers a socket clone
//! and its join handle, and `stop_all` shuts the sockets down —
//! unblocking any thread parked in a read — then joins every thread.

use std::collections::HashMap;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::thread::JoinHandle;

#[derive(Default)]
pub struct ConnTracker {
    next: AtomicU64,
    live: Mutex<HashMap<u64, Entry>>,
}

#[derive(Default)]
struct Entry {
    stream: Option<TcpStream>,
    handle: Option<JoinHandle<()>>,
}

impl ConnTracker {
    pub fn new() -> ConnTracker {
        ConnTracker::default()
    }

    /// Register a connection before spawning its thread. Returns the
    /// id to pass to [`deregister`](Self::deregister); `None` if the
    /// stream can't be cloned (the caller should still serve it —
    /// it just won't be interruptible on stop).
    pub fn register(&self, stream: &TcpStream) -> Option<u64> {
        let clone = stream.try_clone().ok()?;
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.live
            .lock()
            .unwrap()
            .insert(id, Entry { stream: Some(clone), handle: None });
        Some(id)
    }

    /// Attach the spawned thread's handle so `stop_all` can join it.
    /// A no-op if the connection already deregistered itself (tiny
    /// race between spawn and first register — harmless: the thread
    /// is already gone).
    pub fn attach(&self, id: u64, handle: JoinHandle<()>) {
        if let Some(entry) = self.live.lock().unwrap().get_mut(&id) {
            entry.handle = Some(handle);
        }
    }

    /// Called by the connection thread itself when it finishes
    /// naturally. Drops its own join handle (a thread never joins
    /// itself).
    pub fn deregister(&self, id: u64) {
        self.live.lock().unwrap().remove(&id);
    }

    pub fn len(&self) -> usize {
        self.live.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shut down every live connection socket (which wakes threads
    /// blocked in reads with EOF), then join the threads.
    pub fn stop_all(&self) {
        let entries: Vec<Entry> = {
            let mut live = self.live.lock().unwrap();
            live.drain().map(|(_, e)| e).collect()
        };
        // Two passes: shut all sockets first so every thread unblocks
        // before we start (potentially) waiting on joins.
        for entry in &entries {
            if let Some(stream) = &entry.stream {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
        for entry in entries {
            if let Some(handle) = entry.handle {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpListener;
    use std::sync::Arc;

    #[test]
    fn stop_all_unblocks_and_joins_a_reading_thread() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();

        let tracker = Arc::new(ConnTracker::new());
        let id = tracker.register(&server_side).unwrap();
        let t = Arc::clone(&tracker);
        let handle = std::thread::spawn(move || {
            let mut buf = [0u8; 16];
            // Blocks until stop_all shuts the socket down.
            let _ = (&server_side).read(&mut buf);
            t.deregister(id);
        });
        tracker.attach(id, handle);
        assert_eq!(tracker.len(), 1);
        tracker.stop_all(); // must not hang
        assert!(tracker.is_empty());
    }

    #[test]
    fn natural_exit_deregisters_itself() {
        let tracker = ConnTracker::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let _client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let id = tracker.register(&server_side).unwrap();
        tracker.deregister(id);
        assert!(tracker.is_empty());
        tracker.stop_all(); // nothing to do
    }
}
