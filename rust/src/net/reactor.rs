//! The epoll reactor: nonblocking listeners and connections, a small
//! pool of reactor threads, and a bounded worker pool for handlers.
//!
//! Thread model:
//!
//! * **Reactor threads** (`net-reactor-N`, `reactor_threads` of them)
//!   each own an epoll instance, a slab of connections, and an inbox
//!   (eventfd-woken) for cross-thread messages. They do *only*
//!   `accept`/`read`/`write` and protocol parsing — never inference.
//! * **Worker threads** (`net-worker-N`, `worker_threads` of them)
//!   execute the dispatch closures ([`super::workers`]) and route the
//!   encoded reply back to the owning reactor's inbox.
//!
//! So C open connections cost C × (two buffers + a state machine),
//! not C threads: thread count is O(reactors + workers).
//!
//! Connections are identified by `(slot, generation)` tokens packed
//! into the epoll user-data word; a reply or a stale kernel event for
//! a slot that has since been recycled fails the generation check and
//! is dropped instead of reaching the wrong connection.
//!
//! Shutdown is two-phase, preserving PR 6 drain semantics: `stop()`
//! first closes listeners (`draining`), then stops the worker pool —
//! which finishes every queued job, so in-flight requests still get
//! their replies — and only then flags `finalize`, where reactor
//! threads flush remaining bytes (bounded grace) and close everything.

use super::conn::{ConnProtocol, ProtocolFactory, Reply, Step};
use super::sys::{
    Epoll, EpollEvent, EventFd, EPOLLERR, EPOLLHUP, EPOLLIN, EPOLLOUT, EPOLLRDHUP,
};
use super::workers::{Job, WorkerPool};
use super::{NetConfig, NetMetrics};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// Token layout (the epoll user-data u64): one flag bit picks the kind,
// the low bits carry the identity. Connection generations are masked
// to 30 bits so they never collide with the flag bits.
const TOKEN_CONN: u64 = 1 << 63;
const TOKEN_LISTENER: u64 = 1 << 62;
const TOKEN_WAKE: u64 = 1 << 61;
const GEN_MASK: u32 = 0x3FFF_FFFF;

/// Receive-buffer hard cap: above every protocol-level limit (64 MiB
/// frame / body + a full HTTP head); a peer that exceeds it is not
/// speaking either protocol.
const RBUF_CAP: usize = crate::rpc::frame::MAX_FRAME + (2 << 20);

/// Bounded grace for flushing pending reply bytes during finalize.
const FLUSH_GRACE: Duration = Duration::from_secs(1);

/// Handle to a listener registered with [`Reactor::add_listener`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ListenerId(usize);

enum Msg {
    AddListener { id: usize, listener: TcpListener, proto: Arc<ProtocolFactory> },
    CloseListener { id: usize },
    NewConn { stream: TcpStream, listener: usize, proto: Arc<ProtocolFactory> },
    Done { slot: usize, gen: u32, reply: Reply },
}

/// Cross-thread mailbox for one reactor thread.
struct Inbox {
    queue: Mutex<Vec<Msg>>,
    wake: EventFd,
}

impl Inbox {
    fn push(&self, msg: Msg) {
        self.queue.lock().unwrap().push(msg);
        self.wake.signal();
    }
}

struct Shared {
    cfg: NetConfig,
    workers: WorkerPool,
    inboxes: Vec<Arc<Inbox>>,
    /// Round-robin cursor for distributing accepted connections.
    rr: AtomicUsize,
    next_listener: AtomicUsize,
    /// Live connections across all reactor threads (the
    /// `max_connections` accept gate reads this).
    active: AtomicUsize,
    draining: AtomicBool,
    finalize: AtomicBool,
    metrics: NetMetrics,
}

/// The shared I/O plane. One per process in the assembled server
/// (both listeners bind onto it); standalone servers own a private
/// one.
pub struct Reactor {
    shared: Arc<Shared>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
    stopped: AtomicBool,
}

impl Reactor {
    /// Spin up the reactor + worker threads. Fails (cleanly, nothing
    /// spawned) where epoll is unavailable — callers fall back to the
    /// legacy threaded listeners.
    pub fn start(cfg: &NetConfig, metrics: NetMetrics) -> anyhow::Result<Arc<Reactor>> {
        let nthreads = cfg.reactor_threads.max(1);
        let mut epolls = Vec::with_capacity(nthreads);
        let mut inboxes = Vec::with_capacity(nthreads);
        for _ in 0..nthreads {
            let epoll = Epoll::new()?;
            let wake = EventFd::new()?;
            epoll.add(wake.raw(), EPOLLIN, TOKEN_WAKE)?;
            epolls.push(epoll);
            inboxes.push(Arc::new(Inbox { queue: Mutex::new(Vec::new()), wake }));
        }
        let workers =
            WorkerPool::start(cfg.worker_threads.max(1), Arc::clone(&metrics.dispatch_delay));
        let shared = Arc::new(Shared {
            cfg: cfg.clone(),
            workers,
            inboxes,
            rr: AtomicUsize::new(0),
            next_listener: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            finalize: AtomicBool::new(false),
            metrics,
        });
        let threads = epolls
            .into_iter()
            .enumerate()
            .map(|(idx, epoll)| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("net-reactor-{idx}"))
                    .spawn(move || ReactorThread::new(idx, shared, epoll).run())
            })
            .collect::<Result<Vec<_>, _>>()?;
        crate::log_info!(
            "net reactor up: {} reactor thread(s), {} worker(s)",
            nthreads,
            cfg.worker_threads.max(1)
        );
        Ok(Arc::new(Reactor {
            shared,
            threads: Mutex::new(threads),
            stopped: AtomicBool::new(false),
        }))
    }

    /// Register a bound listener; connections accepted from it get
    /// protocol machines from `proto`. Returns the listener handle
    /// and its local address.
    pub fn add_listener(
        &self,
        listener: TcpListener,
        proto: ProtocolFactory,
    ) -> anyhow::Result<(ListenerId, SocketAddr)> {
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let id = self.shared.next_listener.fetch_add(1, Ordering::SeqCst);
        let owner = id % self.shared.inboxes.len();
        self.shared.inboxes[owner].push(Msg::AddListener {
            id,
            listener,
            proto: Arc::new(proto),
        });
        Ok((ListenerId(id), addr))
    }

    /// Close one listener: stop accepting on it and close its
    /// connections — idle ones now, in-flight ones after their
    /// current reply flushes. Other listeners are untouched.
    pub fn close_listener(&self, id: ListenerId) {
        for inbox in &self.shared.inboxes {
            inbox.push(Msg::CloseListener { id: id.0 });
        }
    }

    /// Live connections across the whole reactor.
    pub fn connections_active(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Graceful full stop (idempotent): close listeners, let the
    /// worker pool finish everything already queued, flush replies,
    /// close all connections, join every thread.
    pub fn stop(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        self.shared.draining.store(true, Ordering::SeqCst);
        for inbox in &self.shared.inboxes {
            inbox.wake.signal();
        }
        // Blocks until every queued job ran; their replies are in the
        // reactor inboxes (and mostly flushed) by the time it returns.
        self.shared.workers.stop();
        self.shared.finalize.store(true, Ordering::SeqCst);
        for inbox in &self.shared.inboxes {
            inbox.wake.signal();
        }
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.stop();
    }
}

// ----------------------------------------------------- worker thread

struct Slot {
    gen: u32,
    conn: Option<Conn>,
}

struct Conn {
    stream: TcpStream,
    proto: Box<dyn ConnProtocol>,
    listener: usize,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    wpos: usize,
    /// A dispatch is in flight on the worker pool; reads are parked
    /// (one request in flight per connection — the kernel socket
    /// buffer is the pipeline backpressure).
    busy: bool,
    close_after_flush: bool,
    /// The kernel reported ERR/HUP while busy; close on completion.
    errored: bool,
    /// Currently-registered epoll mask (MOD only on change).
    interest: u32,
    last_activity: Instant,
    /// First byte of the request being accumulated (feeds
    /// `net.read_to_dispatch_ns`).
    req_start: Option<Instant>,
}

fn queue_write(conn: &mut Conn, bytes: &[u8]) {
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    }
    conn.wbuf.extend_from_slice(bytes);
}

struct ReactorThread {
    idx: usize,
    shared: Arc<Shared>,
    epoll: Epoll,
    listeners: HashMap<usize, (TcpListener, Arc<ProtocolFactory>)>,
    slots: Vec<Slot>,
    free: Vec<usize>,
    scratch: Vec<u8>,
    msgs: Vec<Msg>,
    listeners_closed: bool,
}

impl ReactorThread {
    fn new(idx: usize, shared: Arc<Shared>, epoll: Epoll) -> ReactorThread {
        ReactorThread {
            idx,
            shared,
            epoll,
            listeners: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            scratch: vec![0u8; 16 << 10],
            msgs: Vec::new(),
            listeners_closed: false,
        }
    }

    fn inbox(&self) -> &Arc<Inbox> {
        &self.shared.inboxes[self.idx]
    }

    fn run(mut self) {
        // Wake at least every quarter idle-timeout so sweeping is
        // timely, but never busier than 10ms or lazier than 500ms.
        let tick = (self.shared.cfg.idle_timeout / 4)
            .clamp(Duration::from_millis(10), Duration::from_millis(500));
        let mut events = vec![EpollEvent::zeroed(); 256];
        let mut last_sweep = Instant::now();
        loop {
            self.process_inbox();
            if self.shared.draining.load(Ordering::SeqCst) && !self.listeners_closed {
                self.listeners.clear(); // fds close; epoll deregisters
                self.listeners_closed = true;
            }
            if self.shared.finalize.load(Ordering::SeqCst) {
                self.finalize();
                return;
            }
            let n = match self.epoll.wait(&mut events, tick.as_millis() as i32) {
                Ok(n) => n,
                Err(e) => {
                    crate::log_warn!("epoll_wait failed: {e}");
                    std::thread::sleep(Duration::from_millis(10));
                    0
                }
            };
            for ev in &events[..n] {
                let mask = ev.events;
                let token = ev.data;
                if token == TOKEN_WAKE {
                    self.inbox().wake.drain();
                    self.shared.metrics.wakeups.inc();
                    self.process_inbox();
                } else if token & TOKEN_CONN != 0 {
                    let slot = (token & 0xFFFF_FFFF) as usize;
                    let gen = ((token >> 32) as u32) & GEN_MASK;
                    self.on_conn_event(slot, gen, mask);
                } else if token & TOKEN_LISTENER != 0 {
                    self.on_accept((token & 0xFFFF_FFFF) as usize);
                }
            }
            if last_sweep.elapsed() >= tick {
                self.sweep();
                last_sweep = Instant::now();
            }
        }
    }

    fn process_inbox(&mut self) {
        let mut msgs = std::mem::take(&mut self.msgs);
        msgs.extend(self.inbox().queue.lock().unwrap().drain(..));
        for msg in msgs.drain(..) {
            match msg {
                Msg::AddListener { id, listener, proto } => {
                    if self.shared.draining.load(Ordering::SeqCst) {
                        continue;
                    }
                    let token = TOKEN_LISTENER | id as u64;
                    match self.epoll.add(listener.as_raw_fd(), EPOLLIN, token) {
                        Ok(()) => {
                            self.listeners.insert(id, (listener, proto));
                        }
                        Err(e) => crate::log_warn!("failed to watch listener: {e}"),
                    }
                }
                Msg::CloseListener { id } => self.close_listener(id),
                Msg::NewConn { stream, listener, proto } => {
                    self.install(stream, listener, &proto)
                }
                Msg::Done { slot, gen, reply } => self.on_done(slot, gen, reply),
            }
        }
        self.msgs = msgs; // keep the drained Vec's capacity
    }

    fn close_listener(&mut self, id: usize) {
        self.listeners.remove(&id);
        for si in 0..self.slots.len() {
            let close_now = match self.slots[si].conn.as_mut() {
                Some(c) if c.listener == id => {
                    if c.busy || c.wpos < c.wbuf.len() {
                        // Finish the in-flight request, then close.
                        c.close_after_flush = true;
                        false
                    } else {
                        true
                    }
                }
                _ => false,
            };
            if close_now {
                self.close(si, false);
            } else {
                self.update_interest(si);
            }
        }
    }

    // ------------------------------------------------------- accept

    fn on_accept(&mut self, id: usize) {
        loop {
            let accepted = match self.listeners.get(&id) {
                Some((listener, _)) => listener.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _peer)) => {
                    if self.shared.draining.load(Ordering::SeqCst) {
                        continue; // racing accept during drain: drop
                    }
                    let max = self.shared.cfg.max_connections;
                    if max > 0 && self.shared.active.load(Ordering::SeqCst) >= max {
                        self.shared.metrics.connections_rejected.inc();
                        let reject = &self.listeners[&id].1.reject;
                        let _ = stream.set_nonblocking(true);
                        let _ = (&stream).write(reject);
                        continue; // drop: close sends the queued bytes
                    }
                    self.shared.active.fetch_add(1, Ordering::SeqCst);
                    self.shared.metrics.connections_active.add(1);
                    self.shared.metrics.connections_accepted.inc();
                    let proto = Arc::clone(&self.listeners[&id].1);
                    let n = self.shared.inboxes.len();
                    let target = if n == 1 {
                        self.idx
                    } else {
                        self.shared.rr.fetch_add(1, Ordering::Relaxed) % n
                    };
                    if target == self.idx {
                        self.install(stream, id, &proto);
                    } else {
                        self.shared.inboxes[target].push(Msg::NewConn {
                            stream,
                            listener: id,
                            proto,
                        });
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    crate::log_warn!("accept error: {e}");
                    return;
                }
            }
        }
    }

    fn install(&mut self, stream: TcpStream, listener: usize, proto: &Arc<ProtocolFactory>) {
        if self.shared.finalize.load(Ordering::SeqCst)
            || stream.set_nonblocking(true).is_err()
        {
            self.dec_active();
            return;
        }
        let _ = stream.set_nodelay(true);
        let si = self.free.pop().unwrap_or_else(|| {
            self.slots.push(Slot { gen: 0, conn: None });
            self.slots.len() - 1
        });
        let gen = self.slots[si].gen.wrapping_add(1) & GEN_MASK;
        self.slots[si].gen = gen;
        let token = TOKEN_CONN | ((gen as u64) << 32) | si as u64;
        if let Err(e) = self.epoll.add(stream.as_raw_fd(), EPOLLIN | EPOLLRDHUP, token) {
            crate::log_warn!("failed to watch connection: {e}");
            self.free.push(si);
            self.dec_active();
            return;
        }
        self.slots[si].conn = Some(Conn {
            stream,
            proto: (proto.make)(),
            listener,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            busy: false,
            close_after_flush: false,
            errored: false,
            interest: EPOLLIN | EPOLLRDHUP,
            last_activity: Instant::now(),
            req_start: None,
        });
    }

    // ----------------------------------------------------- conn I/O

    fn on_conn_event(&mut self, si: usize, gen: u32, mask: u32) {
        match self.slots.get(si) {
            Some(slot) if slot.gen == gen && slot.conn.is_some() => {}
            _ => return, // stale event for a recycled slot
        }
        if mask & (EPOLLERR | EPOLLHUP) != 0 {
            let conn = self.slots[si].conn.as_mut().unwrap();
            if conn.busy {
                conn.errored = true; // close when the reply lands
            } else {
                self.close(si, false);
            }
            return;
        }
        if mask & EPOLLOUT != 0 && !self.flush(si) {
            return;
        }
        if mask & (EPOLLIN | EPOLLRDHUP) != 0 {
            self.on_readable(si);
        } else {
            // A flush just completed: pipelined bytes may already
            // hold the next request.
            self.drive(si);
            self.update_interest(si);
        }
    }

    fn on_readable(&mut self, si: usize) {
        let mut close = false;
        {
            let Some(conn) = self.slots[si].conn.as_mut() else { return };
            loop {
                match conn.stream.read(&mut self.scratch) {
                    Ok(0) => {
                        close = true; // EOF
                        break;
                    }
                    Ok(n) => {
                        conn.rbuf.extend_from_slice(&self.scratch[..n]);
                        conn.last_activity = Instant::now();
                        if conn.req_start.is_none() {
                            conn.req_start = Some(conn.last_activity);
                        }
                        if conn.rbuf.len() > RBUF_CAP {
                            close = true; // not speaking our protocols
                            break;
                        }
                        if n < self.scratch.len() {
                            break; // socket drained (level-triggered
                                   // epoll corrects us if not)
                        }
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        close = true;
                        break;
                    }
                }
            }
        }
        if close {
            self.close(si, false);
            return;
        }
        self.drive(si);
        self.update_interest(si);
    }

    /// Run the protocol machine over buffered bytes until it needs
    /// more input, dispatches, or the connection closes.
    fn drive(&mut self, si: usize) {
        loop {
            let gen = match self.slots.get(si) {
                Some(slot) if slot.conn.is_some() => slot.gen,
                _ => return,
            };
            enum Act {
                Flush,
                Submit(Box<dyn FnOnce() -> Reply + Send>, Instant),
                Close,
            }
            let act = {
                let conn = self.slots[si].conn.as_mut().unwrap();
                if conn.busy || conn.close_after_flush {
                    return;
                }
                match conn.proto.advance(&mut conn.rbuf) {
                    Step::NeedMore => return,
                    Step::Interim(bytes) => {
                        queue_write(conn, &bytes);
                        Act::Flush
                    }
                    Step::Reply(reply) => {
                        queue_write(conn, &reply.bytes);
                        if reply.close {
                            conn.close_after_flush = true;
                        }
                        Act::Flush
                    }
                    Step::Dispatch(run) => {
                        conn.busy = true;
                        let received = conn.req_start.take().unwrap_or_else(Instant::now);
                        Act::Submit(run, received)
                    }
                    Step::Close => Act::Close,
                }
            };
            match act {
                Act::Flush => {
                    if !self.flush(si) {
                        return;
                    }
                }
                Act::Submit(run, received) => {
                    let inbox = Arc::clone(self.inbox());
                    let job = Job {
                        run,
                        received,
                        complete: Box::new(move |reply| {
                            inbox.push(Msg::Done { slot: si, gen, reply });
                        }),
                    };
                    if !self.shared.workers.submit(job) {
                        // Pool is shutting down: no reply will come.
                        self.close(si, false);
                    }
                    return;
                }
                Act::Close => {
                    self.close(si, false);
                    return;
                }
            }
        }
    }

    fn on_done(&mut self, si: usize, gen: u32, reply: Reply) {
        match self.slots.get(si) {
            Some(slot) if slot.gen == gen && slot.conn.is_some() => {}
            _ => return, // connection closed while the job ran
        }
        let abandoned = {
            let conn = self.slots[si].conn.as_mut().unwrap();
            conn.busy = false;
            if conn.errored || (reply.bytes.is_empty() && reply.close) {
                // Peer vanished mid-request, or the handler panicked.
                true
            } else {
                queue_write(conn, &reply.bytes);
                if reply.close {
                    conn.close_after_flush = true;
                }
                false
            }
        };
        if abandoned {
            self.close(si, false);
            return;
        }
        if self.flush(si) {
            self.drive(si); // pipelined next request, if any
            self.update_interest(si);
        }
    }

    /// Write as much of the pending buffer as the socket accepts.
    /// Returns `false` if the connection was closed.
    fn flush(&mut self, si: usize) -> bool {
        let mut close = false;
        {
            let Some(conn) = self.slots[si].conn.as_mut() else { return false };
            loop {
                if conn.wpos >= conn.wbuf.len() {
                    conn.wbuf.clear();
                    conn.wpos = 0;
                    close = conn.close_after_flush;
                    break;
                }
                match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                    Ok(0) => {
                        close = true;
                        break;
                    }
                    Ok(n) => {
                        conn.wpos += n;
                        conn.last_activity = Instant::now();
                    }
                    // Partial write: resume on EPOLLOUT (the caller
                    // refreshes interest).
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        close = true;
                        break;
                    }
                }
            }
        }
        if close {
            self.close(si, false);
            return false;
        }
        true
    }

    fn update_interest(&mut self, si: usize) {
        let (fd, token, desired, current) = {
            let Some(slot) = self.slots.get(si) else { return };
            let Some(conn) = slot.conn.as_ref() else { return };
            let mut desired = 0u32;
            if !conn.busy && !conn.close_after_flush {
                desired |= EPOLLIN | EPOLLRDHUP;
            }
            if conn.wpos < conn.wbuf.len() {
                desired |= EPOLLOUT;
            }
            let token = TOKEN_CONN | ((slot.gen as u64) << 32) | si as u64;
            (conn.stream.as_raw_fd(), token, desired, conn.interest)
        };
        if desired != current && self.epoll.modify(fd, desired, token).is_ok() {
            self.slots[si].conn.as_mut().unwrap().interest = desired;
        }
    }

    fn sweep(&mut self) {
        let timeout = self.shared.cfg.idle_timeout;
        let now = Instant::now();
        for si in 0..self.slots.len() {
            let idle = match self.slots[si].conn.as_ref() {
                // Busy connections are waiting on *us*, not idling;
                // everything else — half-sent requests (slow loris),
                // quiet keep-alives, stalled readers — sweeps.
                Some(c) => !c.busy && now.duration_since(c.last_activity) > timeout,
                None => false,
            };
            if idle {
                self.close(si, true);
            }
        }
    }

    fn close(&mut self, si: usize, swept: bool) {
        if let Some(conn) = self.slots[si].conn.take() {
            // Dropping the stream closes the fd, which also removes
            // it from the epoll interest list.
            drop(conn);
            self.free.push(si);
            self.dec_active();
            if swept {
                self.shared.metrics.idle_closed.inc();
            }
        }
    }

    fn dec_active(&mut self) {
        self.shared.active.fetch_sub(1, Ordering::SeqCst);
        self.shared.metrics.connections_active.add(-1);
    }

    /// Final phase of `stop()`: the worker pool has already drained,
    /// so every reply is either flushed or sitting in our inbox.
    /// Flush with a bounded grace, then close everything.
    fn finalize(&mut self) {
        let deadline = Instant::now() + FLUSH_GRACE;
        let mut events = vec![EpollEvent::zeroed(); 64];
        loop {
            self.process_inbox();
            let mut pending = false;
            for si in 0..self.slots.len() {
                if self.slots[si].conn.is_none() {
                    continue;
                }
                if self.flush(si) {
                    let conn = self.slots[si].conn.as_ref().unwrap();
                    if conn.wpos < conn.wbuf.len() {
                        pending = true;
                    }
                }
            }
            if !pending || Instant::now() >= deadline {
                break;
            }
            let _ = self.epoll.wait(&mut events, 20);
        }
        for si in 0..self.slots.len() {
            self.close(si, false);
        }
    }
}
