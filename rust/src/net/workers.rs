//! Bounded worker pool: runs request handlers off the reactor thread.
//!
//! The reactor parses a request and submits a [`Job`]; a worker runs
//! the handler closure (which produces fully encoded reply bytes) and
//! invokes the job's completion, which routes the reply back to the
//! reactor thread owning the connection. The pool is the *only* place
//! `ServerCore::handle` runs on the reactor path, so the process
//! serves C connections with O(workers + reactors) threads — the
//! reactor itself never blocks on inference.
//!
//! A panicking handler is caught here: the worker survives, the
//! connection gets closed (empty reply, `close`), and every other
//! connection is unaffected.

use super::conn::Reply;
use crate::util::metrics::Histogram;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One parsed request, ready to execute.
pub struct Job {
    /// Runs the handler; returns encoded reply bytes.
    pub run: Box<dyn FnOnce() -> Reply + Send>,
    /// When the request's first byte arrived (feeds the
    /// `net.read_to_dispatch_ns` histogram: ingress latency, separable
    /// from batch queue delay measured further down).
    pub received: Instant,
    /// Routes the reply back to the owning reactor thread.
    pub complete: Box<dyn FnOnce(Reply) + Send>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    /// `net.read_to_dispatch_ns`.
    dispatch_delay: Arc<Histogram>,
}

pub struct WorkerPool {
    shared: Arc<Shared>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl WorkerPool {
    pub fn start(workers: usize, dispatch_delay: Arc<Histogram>) -> WorkerPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            dispatch_delay,
        });
        let threads = (0..workers.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("net-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn net worker")
            })
            .collect();
        WorkerPool { shared, threads: Mutex::new(threads) }
    }

    /// Enqueue a job. `false` once the pool is shutting down — the
    /// caller should close the connection instead of waiting on a
    /// reply that will never come.
    pub fn submit(&self, job: Job) -> bool {
        if self.shared.shutdown.load(Ordering::SeqCst) {
            return false;
        }
        self.shared.queue.lock().unwrap().push_back(job);
        self.shared.available.notify_one();
        true
    }

    /// Graceful stop: workers finish every queued job (replies still
    /// route back to the reactors), then exit; blocks until all have.
    pub fn stop(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                // Drain-then-exit: the queue is checked before the
                // flag, so in-flight work admitted before shutdown
                // always completes.
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        shared.dispatch_delay.record_duration(job.received.elapsed());
        let reply = match catch_unwind(AssertUnwindSafe(job.run)) {
            Ok(reply) => reply,
            Err(_) => {
                crate::log_error!("handler panicked; closing its connection");
                Reply { bytes: Vec::new(), close: true }
            }
        };
        (job.complete)(reply);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn job(counter: &Arc<AtomicUsize>, done: &Arc<AtomicUsize>) -> Job {
        let (c, d) = (Arc::clone(counter), Arc::clone(done));
        Job {
            run: Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
                Reply { bytes: vec![1], close: false }
            }),
            received: Instant::now(),
            complete: Box::new(move |reply| {
                assert_eq!(reply.bytes, vec![1]);
                d.fetch_add(1, Ordering::SeqCst);
            }),
        }
    }

    #[test]
    fn stop_drains_queued_jobs() {
        let hist = Arc::new(Histogram::new());
        let pool = WorkerPool::start(2, Arc::clone(&hist));
        let ran = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            assert!(pool.submit(job(&ran, &done)));
        }
        pool.stop();
        assert_eq!(ran.load(Ordering::SeqCst), 32);
        assert_eq!(done.load(Ordering::SeqCst), 32);
        assert_eq!(hist.count(), 32);
        // Post-stop submits are refused, not silently dropped.
        assert!(!pool.submit(job(&ran, &done)));
    }

    #[test]
    fn panicking_handler_closes_conn_but_worker_survives() {
        let pool = WorkerPool::start(1, Arc::new(Histogram::new()));
        let closed = Arc::new(AtomicBool::new(false));
        let c = Arc::clone(&closed);
        pool.submit(Job {
            run: Box::new(|| panic!("injected")),
            received: Instant::now(),
            complete: Box::new(move |reply| {
                assert!(reply.close);
                c.store(true, Ordering::SeqCst);
            }),
        });
        // The single worker survived the panic and still runs jobs.
        let ran = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));
        pool.submit(job(&ran, &done));
        pool.stop();
        assert!(closed.load(Ordering::SeqCst));
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }
}
