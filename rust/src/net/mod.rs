//! Event-driven I/O plane shared by both listeners.
//!
//! The RPC and HTTP servers used to be naive thread-per-connection
//! accept loops — C open connections cost C blocked OS threads, which
//! exhausts the scheduler long before the batcher or tensor pools
//! saturate. This subsystem replaces that with a small epoll reactor
//! pool ([`reactor`]) driving per-connection protocol state machines
//! ([`conn`]) and a bounded worker pool ([`workers`]) that runs
//! `ServerCore::handle` off the reactor threads, so thread count is
//! O(`reactor_threads` + `worker_threads`) regardless of connection
//! count.
//!
//! Layout:
//! * [`sys`] — dependency-free epoll/eventfd/rlimit syscall shim
//! * [`conn`] — RPC-framing and HTTP/1.1 keep-alive state machines
//!   with partial read/write resumption
//! * [`workers`] — bounded handler pool with drain-then-exit stop
//! * [`reactor`] — the event loop: accept gate, idle sweep, two-phase
//!   graceful stop
//! * [`track`] — connection joining for the legacy threaded mode
//!   (kept behind `net.mode = "threaded"`; removal is a ROADMAP
//!   follow-up)
//!
//! Configured via `ServerConfig.net` (`net.*` keys in server.conf);
//! observable via `net.*` metrics in the shared registry.

pub mod conn;
pub mod reactor;
pub mod sys;
pub mod track;
pub mod workers;

pub use conn::{ConnProtocol, ProtocolFactory, Reply, Step};
pub use reactor::{ListenerId, Reactor};
pub use track::ConnTracker;
pub use workers::{Job, WorkerPool};

use crate::util::metrics::{Counter, Gauge, Histogram, Registry};
use std::sync::Arc;
use std::time::Duration;

/// Which I/O plane the listeners bind onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetMode {
    /// Shared epoll reactor (default).
    Reactor,
    /// Legacy thread-per-connection accept loops. Also the automatic
    /// fallback where epoll is unavailable (non-Linux).
    Threaded,
}

/// `ServerConfig.net` — knobs for the I/O plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetConfig {
    pub mode: NetMode,
    /// Reactor (event-loop) threads; each owns an epoll instance and
    /// a share of the connections.
    pub reactor_threads: usize,
    /// Handler threads executing `ServerCore::handle`; bounds request
    /// concurrency upstream of the admission gate.
    pub worker_threads: usize,
    /// Accept gate: connections above this are answered with an
    /// immediate 503/`Unavailable` and closed. 0 = unlimited.
    pub max_connections: usize,
    /// Idle sweep: connections (including half-sent requests — slow
    /// loris) with no activity for this long are closed. Replaces the
    /// old hardcoded 60s read timeout; also applied as the read
    /// timeout in threaded mode.
    pub idle_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            mode: NetMode::Reactor,
            reactor_threads: 1,
            worker_threads: 4,
            max_connections: 0,
            idle_timeout: Duration::from_secs(60),
        }
    }
}

/// `net.*` instruments, registered in the shared [`Registry`] so they
/// render on `/metrics` (as `tensorserve_net_*`).
#[derive(Clone)]
pub struct NetMetrics {
    pub connections_accepted: Arc<Counter>,
    pub connections_rejected: Arc<Counter>,
    pub idle_closed: Arc<Counter>,
    pub wakeups: Arc<Counter>,
    pub connections_active: Arc<Gauge>,
    /// First request byte → handler dispatch, in ns: ingress latency,
    /// separable from batch queue delay measured further down.
    pub dispatch_delay: Arc<Histogram>,
}

impl NetMetrics {
    pub fn register(registry: &Registry) -> NetMetrics {
        NetMetrics {
            connections_accepted: registry.counter("net.connections_accepted"),
            connections_rejected: registry.counter("net.connections_rejected"),
            idle_closed: registry.counter("net.idle_closed"),
            wakeups: registry.counter("net.reactor_wakeups"),
            connections_active: registry.gauge("net.connections_active"),
            dispatch_delay: registry.histogram("net.read_to_dispatch_ns"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_reactor_mode_with_sane_bounds() {
        let cfg = NetConfig::default();
        assert_eq!(cfg.mode, NetMode::Reactor);
        assert_eq!(cfg.reactor_threads, 1);
        assert_eq!(cfg.worker_threads, 4);
        assert_eq!(cfg.max_connections, 0);
        assert_eq!(cfg.idle_timeout, Duration::from_secs(60));
    }

    #[test]
    fn metrics_register_under_net_names() {
        let registry = Registry::new();
        let m = NetMetrics::register(&registry);
        m.connections_accepted.inc();
        m.connections_active.add(1);
        m.dispatch_delay.record(1_000);
        let text = registry.render_prometheus("tensorserve");
        assert!(text.contains("tensorserve_net_connections_accepted"));
        assert!(text.contains("tensorserve_net_connections_active"));
        assert!(text.contains("tensorserve_net_read_to_dispatch_ns"));
    }
}
