//! Thin syscall shim for the reactor — `epoll`, `eventfd`, rlimits.
//!
//! Dependency-free by the same rule as [`crate::util::mem`]: we
//! declare the handful of symbols we need against the libc `std`
//! already links instead of pulling in the `libc` crate. Everything
//! is wrapped in safe types built on `std::os::fd` ownership
//! (`OwnedFd` closes on drop, so no fd ever leaks on an error path).
//!
//! On non-Linux targets the constructors return
//! `io::ErrorKind::Unsupported`; callers ([`super::reactor`]) surface
//! that and the servers fall back to the legacy threaded mode, so the
//! crate still compiles and serves everywhere.

use std::io::{self, Read, Write};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};

// epoll event mask bits (linux uapi eventpoll.h).
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;

#[cfg(target_os = "linux")]
const EPOLL_CTL_ADD: i32 = 1;
#[cfg(target_os = "linux")]
const EPOLL_CTL_DEL: i32 = 2;
#[cfg(target_os = "linux")]
const EPOLL_CTL_MOD: i32 = 3;
#[cfg(target_os = "linux")]
const EPOLL_CLOEXEC: i32 = 0o2000000;
#[cfg(target_os = "linux")]
const EFD_CLOEXEC: i32 = 0o2000000;
#[cfg(target_os = "linux")]
const EFD_NONBLOCK: i32 = 0o4000;

/// One readiness event. The kernel's `struct epoll_event` is packed
/// on x86-64 (`EPOLL_PACKED`) and naturally aligned elsewhere — the
/// layout must match exactly or `epoll_wait` scribbles garbage.
#[derive(Clone, Copy)]
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
pub struct EpollEvent {
    pub events: u32,
    /// Caller-chosen token identifying the fd (see `reactor` tokens).
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

#[cfg(target_os = "linux")]
extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
}

#[cfg(target_os = "linux")]
fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

#[cfg(not(target_os = "linux"))]
fn unsupported<T>() -> io::Result<T> {
    Err(io::Error::new(io::ErrorKind::Unsupported, "epoll reactor requires linux"))
}

/// An epoll instance. Registered fds are identified by a caller
/// token; closing a registered fd (dropping its `TcpStream`)
/// deregisters it in the kernel automatically.
pub struct Epoll {
    fd: OwnedFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        #[cfg(target_os = "linux")]
        {
            let fd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
            Ok(Epoll { fd: unsafe { OwnedFd::from_raw_fd(fd) } })
        }
        #[cfg(not(target_os = "linux"))]
        unsupported()
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        {
            let mut ev = EpollEvent { events, data: token };
            cvt(unsafe { epoll_ctl(self.fd.as_raw_fd(), op, fd, &mut ev) })?;
            Ok(())
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = (op, fd, events, token);
            unsupported()
        }
    }

    pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        return self.ctl(EPOLL_CTL_ADD, fd, events, token);
        #[cfg(not(target_os = "linux"))]
        self.ctl(0, fd, events, token)
    }

    pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        return self.ctl(EPOLL_CTL_MOD, fd, events, token);
        #[cfg(not(target_os = "linux"))]
        self.ctl(0, fd, events, token)
    }

    pub fn delete(&self, fd: RawFd) -> io::Result<()> {
        #[cfg(target_os = "linux")]
        return self.ctl(EPOLL_CTL_DEL, fd, 0, 0);
        #[cfg(not(target_os = "linux"))]
        self.ctl(0, fd, 0, 0)
    }

    /// Wait up to `timeout_ms` (-1 = forever) and fill `events`.
    /// `EINTR` is reported as zero events, not an error.
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        #[cfg(target_os = "linux")]
        {
            let n = unsafe {
                epoll_wait(
                    self.fd.as_raw_fd(),
                    events.as_mut_ptr(),
                    events.len().min(i32::MAX as usize) as i32,
                    timeout_ms,
                )
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(e);
            }
            Ok(n as usize)
        }
        #[cfg(not(target_os = "linux"))]
        {
            let _ = (events, timeout_ms);
            unsupported()
        }
    }
}

/// Cross-thread wakeup: an 8-byte counter fd, nonblocking on both
/// ends. `signal` is async-signal-safe cheap (one `write`); `drain`
/// resets the counter so level-triggered epoll stops reporting it.
pub struct EventFd {
    file: std::fs::File,
}

impl EventFd {
    pub fn new() -> io::Result<EventFd> {
        #[cfg(target_os = "linux")]
        {
            let fd = cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?;
            Ok(EventFd { file: unsafe { std::fs::File::from_raw_fd(fd) } })
        }
        #[cfg(not(target_os = "linux"))]
        unsupported()
    }

    pub fn raw(&self) -> RawFd {
        self.file.as_raw_fd()
    }

    /// Wake the reactor owning this fd. Best effort: a full counter
    /// (u64::MAX pending wakeups) means the reactor is already awake.
    pub fn signal(&self) {
        let _ = (&self.file).write(&1u64.to_ne_bytes());
    }

    /// Consume all pending wakeups (one read resets the counter).
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        let _ = (&self.file).read(&mut buf);
    }
}

// ------------------------------------------------- process utilities

#[cfg(target_os = "linux")]
#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

#[cfg(target_os = "linux")]
extern "C" {
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

#[cfg(target_os = "linux")]
const RLIMIT_NOFILE: i32 = 7;

/// Raise the fd soft limit toward `want` (capped by the hard limit).
/// Returns the effective soft limit. Used by the C1k scaling test and
/// bench, where 1000 client + 1000 server sockets exceed the common
/// 1024 default.
pub fn raise_nofile_limit(want: u64) -> u64 {
    #[cfg(target_os = "linux")]
    {
        let mut lim = RLimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return 1024;
        }
        if lim.cur >= want {
            return lim.cur;
        }
        let target = want.min(lim.max);
        let new = RLimit { cur: target, max: lim.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
            target
        } else {
            lim.cur
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = want;
        1024
    }
}

/// Live thread count of this process (`/proc/self/task`), `None` when
/// unavailable. The scaling test asserts this stays O(workers +
/// reactors) while 1k connections are open.
pub fn process_thread_count() -> Option<usize> {
    let entries = std::fs::read_dir("/proc/self/task").ok()?;
    Some(entries.count())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(target_os = "linux")]
    #[test]
    fn eventfd_signal_then_drain_is_readable_once() {
        let efd = EventFd::new().unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(efd.raw(), EPOLLIN, 7).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        // Nothing pending: a zero-timeout wait reports no events.
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        efd.signal();
        efd.signal();
        let n = ep.wait(&mut events, 1000).unwrap();
        assert_eq!(n, 1);
        let (ev, data) = (events[0].events, events[0].data);
        assert_ne!(ev & EPOLLIN, 0);
        assert_eq!(data, 7);
        // Draining resets the counter; the level-triggered fd goes
        // quiet again.
        efd.drain();
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn epoll_reports_listener_readability() {
        use std::io::Write as _;
        use std::net::{TcpListener, TcpStream};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let ep = Epoll::new().unwrap();
        ep.add(listener.as_raw_fd(), EPOLLIN, 42).unwrap();
        let mut events = [EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut events, 0).unwrap(), 0);
        let mut client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        client.write_all(b"x").unwrap();
        let n = ep.wait(&mut events, 2000).unwrap();
        assert_eq!(n, 1);
        assert_eq!({ events[0].data }, 42);
        ep.delete(listener.as_raw_fd()).unwrap();
    }

    #[test]
    fn thread_count_and_rlimit_helpers_answer() {
        #[cfg(target_os = "linux")]
        assert!(process_thread_count().unwrap() >= 1);
        assert!(raise_nofile_limit(64) >= 64 || cfg!(not(target_os = "linux")));
    }
}
