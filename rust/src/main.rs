//! `tensorserve_server` — the canonical model server binary (paper §3).
//!
//! ```text
//! tensorserve_server --config server.json
//! tensorserve_server --models mlp_classifier,toy_table:table --port 8500
//! tensorserve_server --models mlp_classifier --http_port 8501   # + REST
//! ```
//!
//! With `--config`, the JSON file is the full `ModelServerConfig`
//! (see `server::config`). Without it, `--models` gives a quick
//! comma-separated list of `name[:platform]` entries served from
//! `--artifacts` with latest-version policy — the "casual deployment"
//! default of §2.1.1.

use std::path::PathBuf;
use std::time::Duration;
use tensorserve::lifecycle::source::ServingPolicy;
use tensorserve::server::builder::ModelServer;
use tensorserve::server::config::{ModelConfig, ServerConfig};
use tensorserve::util::argparse::Flags;

fn main() -> anyhow::Result<()> {
    let mut flags = Flags::new(
        "tensorserve_server",
        "TensorFlow-Serving reproduction: canonical model server",
    );
    flags.flag("config", "", "path to a JSON ModelServerConfig");
    flags.flag("port", "8500", "listen port (overrides config)");
    flags.flag(
        "http_port",
        "0",
        "HTTP/REST gateway port (0 = disabled unless the config sets http_addr)",
    );
    flags.flag(
        "models",
        "mlp_classifier,mlp_regressor,toy_table:table",
        "comma-separated name[:platform] list (used when --config is empty)",
    );
    flags.flag("artifacts", "", "artifacts root (default: repo artifacts/)");
    flags.flag("poll_interval_ms", "500", "file-system source poll interval");
    flags.bool_flag("resource_preserving", "use the resource-preserving version policy");
    let parsed = flags.parse_or_exit();

    let mut config = if parsed.get("config").is_empty() {
        let artifacts_root = if parsed.get("artifacts").is_empty() {
            tensorserve::runtime::artifacts::default_artifacts_root()
        } else {
            PathBuf::from(parsed.get("artifacts"))
        };
        let models = parsed
            .get("models")
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|entry| {
                let (name, platform) = match entry.split_once(':') {
                    Some((n, p)) => (n.to_string(), p.to_string()),
                    None => (entry.to_string(), "hlo".to_string()),
                };
                ModelConfig {
                    base_path: artifacts_root.join(&name),
                    name,
                    platform,
                    policy: ServingPolicy::Latest(1),
                }
            })
            .collect();
        ServerConfig {
            artifacts_root,
            models,
            poll_interval: Some(Duration::from_millis(parsed.get_u64("poll_interval_ms"))),
            availability_preserving: !parsed.get_bool("resource_preserving"),
            ..Default::default()
        }
    } else {
        ServerConfig::load(&PathBuf::from(parsed.get("config")))?
    };
    config.port = parsed.get_u64("port") as u16;
    let http_port = parsed.get_u64("http_port");
    if http_port != 0 {
        config.http_addr = Some(format!("0.0.0.0:{http_port}"));
    }

    let server = ModelServer::start(config)?;
    eprintln!("tensorserve_server listening on {}", server.addr());
    if let Some(http) = server.http_addr() {
        eprintln!("REST gateway listening on http://{http}/v1/models/...");
    }
    let ready = server.wait_until_ready(Duration::from_secs(300))?;
    eprintln!("models ready: {ready:?}");

    // Serve until killed.
    loop {
        std::thread::park();
    }
}
