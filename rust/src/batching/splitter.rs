//! Large-request splitting: a request bigger than `max_batch_size` is
//! divided into chunks that batch independently, and the caller's
//! completion fires when the *last* chunk finishes (mirrors TF-Serving's
//! `split_input_task_func`).

use super::batch::BatchTask;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Tasks that can split themselves into chunks of bounded size.
pub trait SplittableTask: BatchTask + Sized {
    /// Split into parts each with `size() <= max_part_size`.
    /// Order must be preserved (part i precedes part i+1).
    fn split(self, max_part_size: usize) -> Vec<Self>;
}

/// Completion rendezvous for a split task: the original completion
/// callback runs exactly once, when every chunk has completed.
pub struct SplitCompletion {
    remaining: AtomicUsize,
    on_done: Box<dyn Fn() + Send + Sync>,
}

impl SplitCompletion {
    pub fn new(parts: usize, on_done: impl Fn() + Send + Sync + 'static) -> Arc<Self> {
        assert!(parts > 0);
        Arc::new(SplitCompletion {
            remaining: AtomicUsize::new(parts),
            on_done: Box::new(on_done),
        })
    }

    /// Mark one chunk done; fires the callback on the last one.
    pub fn part_done(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            (self.on_done)();
        }
    }

    pub fn remaining(&self) -> usize {
        self.remaining.load(Ordering::Acquire)
    }
}

/// Split `task` if needed and return the parts (1 part = no split).
pub fn split_if_needed<T: SplittableTask>(task: T, max_batch_size: usize) -> Vec<T> {
    if task.size() <= max_batch_size {
        vec![task]
    } else {
        let parts = task.split(max_batch_size);
        debug_assert!(parts.iter().all(|p| p.size() <= max_batch_size));
        parts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[derive(Debug, Clone, PartialEq)]
    struct Rows(Vec<u32>);

    impl BatchTask for Rows {
        fn size(&self) -> usize {
            self.0.len()
        }
    }

    impl SplittableTask for Rows {
        fn split(self, max: usize) -> Vec<Self> {
            self.0.chunks(max).map(|c| Rows(c.to_vec())).collect()
        }
    }

    #[test]
    fn small_task_not_split() {
        let parts = split_if_needed(Rows(vec![1, 2]), 4);
        assert_eq!(parts, vec![Rows(vec![1, 2])]);
    }

    #[test]
    fn large_task_split_preserving_order() {
        let parts = split_if_needed(Rows((0..10).collect()), 4);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], Rows(vec![0, 1, 2, 3]));
        assert_eq!(parts[2], Rows(vec![8, 9]));
        let rejoined: Vec<u32> = parts.into_iter().flat_map(|p| p.0).collect();
        assert_eq!(rejoined, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn completion_fires_once_after_all_parts() {
        static FIRED: AtomicU32 = AtomicU32::new(0);
        let c = SplitCompletion::new(3, || {
            FIRED.fetch_add(1, Ordering::SeqCst);
        });
        c.part_done();
        c.part_done();
        assert_eq!(FIRED.load(Ordering::SeqCst), 0);
        assert_eq!(c.remaining(), 1);
        c.part_done();
        assert_eq!(FIRED.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn completion_concurrent_parts() {
        let fired = Arc::new(AtomicU32::new(0));
        let f = Arc::clone(&fired);
        let c = SplitCompletion::new(16, move || {
            f.fetch_add(1, Ordering::SeqCst);
        });
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || c.part_done())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }
}
