//! Large-request splitting: a request bigger than `max_batch_size` is
//! divided into chunks that batch independently, and the caller's
//! completion fires when the *last* chunk finishes (mirrors TF-Serving's
//! `split_input_task_func`).
//!
//! Dispatch is **parallel**: callers enqueue every chunk before
//! waiting on any (see `BatchingSession::run_split`), and the
//! scheduler's lanes let multiple device workers drain one lane's
//! chunk backlog concurrently, so a split request's latency tracks the
//! slowest single chunk rather than the sum of all chunks. The
//! [`SplitCompletion`] rendezvous here is the generic form of that
//! last-chunk completion for non-tensor tasks.

use super::batch::BatchTask;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Tasks that can split themselves into chunks of bounded size.
pub trait SplittableTask: BatchTask + Sized {
    /// Split into parts each with `size() <= max_part_size`.
    /// Order must be preserved (part i precedes part i+1).
    fn split(self, max_part_size: usize) -> Vec<Self>;
}

/// Completion rendezvous for a split task: the original completion
/// callback runs exactly once, when every chunk has completed.
pub struct SplitCompletion {
    remaining: AtomicUsize,
    on_done: Box<dyn Fn() + Send + Sync>,
}

impl SplitCompletion {
    pub fn new(parts: usize, on_done: impl Fn() + Send + Sync + 'static) -> Arc<Self> {
        assert!(parts > 0);
        Arc::new(SplitCompletion {
            remaining: AtomicUsize::new(parts),
            on_done: Box::new(on_done),
        })
    }

    /// Mark one chunk done; fires the callback on the last one.
    pub fn part_done(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            (self.on_done)();
        }
    }

    pub fn remaining(&self) -> usize {
        self.remaining.load(Ordering::Acquire)
    }
}

/// Split `task` if needed and return the parts (1 part = no split).
pub fn split_if_needed<T: SplittableTask>(task: T, max_batch_size: usize) -> Vec<T> {
    if task.size() <= max_batch_size {
        vec![task]
    } else {
        let parts = task.split(max_batch_size);
        debug_assert!(parts.iter().all(|p| p.size() <= max_batch_size));
        parts
    }
}

/// Row-chunk sizes covering `total` rows with parts of at most `max`:
/// `chunk_sizes(10, 4) == [4, 4, 2]`. The shared shape arithmetic
/// behind tensor splitting (`BatchingSession::run` uses it to divide
/// oversized requests into zero-copy views).
pub fn chunk_sizes(total: usize, max: usize) -> Vec<usize> {
    assert!(max > 0, "chunk size must be positive");
    let mut out = Vec::with_capacity((total + max - 1) / max);
    let mut left = total;
    while left > 0 {
        let s = left.min(max);
        out.push(s);
        left -= s;
    }
    if out.is_empty() {
        out.push(0); // a 0-row task still needs one (empty) part
    }
    out
}

impl BatchTask for crate::base::tensor::Tensor {
    fn size(&self) -> usize {
        self.batch()
    }
}

/// Tensors split along the batch dimension into **views**: every part
/// shares the parent's storage — splitting a request costs O(parts)
/// metadata, never a copy.
impl SplittableTask for crate::base::tensor::Tensor {
    fn split(self, max_part_size: usize) -> Vec<Self> {
        let sizes = chunk_sizes(self.batch(), max_part_size);
        // Infallible: chunk sizes sum to the batch by construction.
        crate::base::tensor::Tensor::split(&self, &sizes).expect("chunk sizes cover batch")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[derive(Debug, Clone, PartialEq)]
    struct Rows(Vec<u32>);

    impl BatchTask for Rows {
        fn size(&self) -> usize {
            self.0.len()
        }
    }

    impl SplittableTask for Rows {
        fn split(self, max: usize) -> Vec<Self> {
            self.0.chunks(max).map(|c| Rows(c.to_vec())).collect()
        }
    }

    #[test]
    fn small_task_not_split() {
        let parts = split_if_needed(Rows(vec![1, 2]), 4);
        assert_eq!(parts, vec![Rows(vec![1, 2])]);
    }

    #[test]
    fn large_task_split_preserving_order() {
        let parts = split_if_needed(Rows((0..10).collect()), 4);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], Rows(vec![0, 1, 2, 3]));
        assert_eq!(parts[2], Rows(vec![8, 9]));
        let rejoined: Vec<u32> = parts.into_iter().flat_map(|p| p.0).collect();
        assert_eq!(rejoined, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn completion_fires_once_after_all_parts() {
        static FIRED: AtomicU32 = AtomicU32::new(0);
        let c = SplitCompletion::new(3, || {
            FIRED.fetch_add(1, Ordering::SeqCst);
        });
        c.part_done();
        c.part_done();
        assert_eq!(FIRED.load(Ordering::SeqCst), 0);
        assert_eq!(c.remaining(), 1);
        c.part_done();
        assert_eq!(FIRED.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn chunk_sizes_cover_exactly() {
        assert_eq!(chunk_sizes(10, 4), vec![4, 4, 2]);
        assert_eq!(chunk_sizes(8, 4), vec![4, 4]);
        assert_eq!(chunk_sizes(3, 4), vec![3]);
        assert_eq!(chunk_sizes(0, 4), vec![0]);
        for (total, max) in [(1usize, 1usize), (17, 5), (100, 7)] {
            let c = chunk_sizes(total, max);
            assert_eq!(c.iter().sum::<usize>(), total);
            assert!(c.iter().all(|&s| s <= max));
        }
    }

    #[test]
    fn tensor_split_parts_are_views() {
        use crate::base::tensor::Tensor;
        let t = Tensor::matrix((0..10).map(|i| vec![i as f32, 0.0]).collect()).unwrap();
        let parent = t.clone();
        let parts = split_if_needed(t, 4);
        assert_eq!(parts.len(), 3);
        assert_eq!(
            parts.iter().map(|p| p.batch()).collect::<Vec<_>>(),
            vec![4, 4, 2]
        );
        for p in &parts {
            assert!(p.shares_storage(&parent), "splitter copied tensor rows");
        }
        assert_eq!(parts[2].row(1), &[9.0, 0.0]);
    }

    #[test]
    fn completion_concurrent_parts() {
        let fired = Arc::new(AtomicU32::new(0));
        let f = Arc::clone(&fired);
        let c = SplitCompletion::new(16, move || {
            f.fetch_add(1, Ordering::SeqCst);
        });
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || c.part_done())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }
}
