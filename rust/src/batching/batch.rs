//! Templatized batching primitives: the core library is generic over
//! "the type of request being batched (be it tensors or some other
//! data)" — §2.2.1.

use std::time::Instant;

/// A unit of batchable work. `size()` is in task-defined units (e.g.
/// examples in a request); the scheduler packs batches so the summed
/// size stays within `max_batch_size`. `deadline()` is the wall-clock
/// instant after which executing the task is wasted device time — the
/// scheduler picks nearest-deadline batches first (EDF) and the
/// processor drops expired tasks before the device call.
pub trait BatchTask: Send + 'static {
    fn size(&self) -> usize;

    /// Latest useful completion time; `None` = no deadline.
    fn deadline(&self) -> Option<Instant> {
        None
    }
}

/// A merged group of tasks processed in one device invocation.
pub struct Batch<T: BatchTask> {
    tasks: Vec<T>,
    /// Nanos timestamp (scheduler clock) when the first task arrived.
    opened_at_nanos: u64,
    /// Min over member deadlines, maintained on push (O(1) reads for
    /// the scheduler's EDF pick). `None` = no member has a deadline.
    earliest_deadline: Option<Instant>,
}

impl<T: BatchTask> Batch<T> {
    pub fn new(opened_at_nanos: u64) -> Self {
        Batch { tasks: Vec::new(), opened_at_nanos, earliest_deadline: None }
    }

    pub fn push(&mut self, task: T) {
        if let Some(d) = task.deadline() {
            self.earliest_deadline = Some(match self.earliest_deadline {
                Some(prev) => prev.min(d),
                None => d,
            });
        }
        self.tasks.push(task);
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Sum of task sizes (the merged batch size).
    pub fn size(&self) -> usize {
        self.tasks.iter().map(|t| t.size()).sum()
    }

    pub fn opened_at_nanos(&self) -> u64 {
        self.opened_at_nanos
    }

    /// Nearest member deadline; `None` = unconstrained.
    pub fn earliest_deadline(&self) -> Option<Instant> {
        self.earliest_deadline
    }

    pub fn tasks(&self) -> &[T] {
        &self.tasks
    }

    pub fn into_tasks(self) -> Vec<T> {
        self.tasks
    }
}

impl<T: BatchTask> IntoIterator for Batch<T> {
    type Item = T;
    type IntoIter = std::vec::IntoIter<T>;
    fn into_iter(self) -> Self::IntoIter {
        self.tasks.into_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    struct Sized(usize);
    impl BatchTask for Sized {
        fn size(&self) -> usize {
            self.0
        }
    }

    struct Timed(usize, Option<Instant>);
    impl BatchTask for Timed {
        fn size(&self) -> usize {
            self.0
        }
        fn deadline(&self) -> Option<Instant> {
            self.1
        }
    }

    #[test]
    fn batch_accumulates_size() {
        let mut b = Batch::new(42);
        assert!(b.is_empty());
        b.push(Sized(3));
        b.push(Sized(5));
        assert_eq!(b.len(), 2);
        assert_eq!(b.size(), 8);
        assert_eq!(b.opened_at_nanos(), 42);
        // Tasks without deadlines leave the batch unconstrained.
        assert_eq!(b.earliest_deadline(), None);
    }

    #[test]
    fn into_tasks_preserves_order() {
        let mut b = Batch::new(0);
        for i in 0..5 {
            b.push(Sized(i));
        }
        let sizes: Vec<usize> = b.into_tasks().iter().map(|t| t.0).collect();
        assert_eq!(sizes, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn earliest_deadline_tracks_min() {
        let t0 = Instant::now();
        let near = t0 + Duration::from_millis(10);
        let far = t0 + Duration::from_millis(500);
        let mut b = Batch::new(0);
        b.push(Timed(1, None));
        assert_eq!(b.earliest_deadline(), None);
        b.push(Timed(1, Some(far)));
        assert_eq!(b.earliest_deadline(), Some(far));
        b.push(Timed(1, Some(near)));
        assert_eq!(b.earliest_deadline(), Some(near));
        // A later deadline never loosens the batch's constraint.
        b.push(Timed(1, Some(far)));
        assert_eq!(b.earliest_deadline(), Some(near));
    }
}
