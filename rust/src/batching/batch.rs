//! Templatized batching primitives: the core library is generic over
//! "the type of request being batched (be it tensors or some other
//! data)" — §2.2.1.

/// A unit of batchable work. `size()` is in task-defined units (e.g.
/// examples in a request); the scheduler packs batches so the summed
/// size stays within `max_batch_size`.
pub trait BatchTask: Send + 'static {
    fn size(&self) -> usize;
}

/// A merged group of tasks processed in one device invocation.
pub struct Batch<T: BatchTask> {
    tasks: Vec<T>,
    /// Nanos timestamp (scheduler clock) when the first task arrived.
    opened_at_nanos: u64,
}

impl<T: BatchTask> Batch<T> {
    pub fn new(opened_at_nanos: u64) -> Self {
        Batch { tasks: Vec::new(), opened_at_nanos }
    }

    pub fn push(&mut self, task: T) {
        self.tasks.push(task);
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Sum of task sizes (the merged batch size).
    pub fn size(&self) -> usize {
        self.tasks.iter().map(|t| t.size()).sum()
    }

    pub fn opened_at_nanos(&self) -> u64 {
        self.opened_at_nanos
    }

    pub fn tasks(&self) -> &[T] {
        &self.tasks
    }

    pub fn into_tasks(self) -> Vec<T> {
        self.tasks
    }
}

impl<T: BatchTask> IntoIterator for Batch<T> {
    type Item = T;
    type IntoIter = std::vec::IntoIter<T>;
    fn into_iter(self) -> Self::IntoIter {
        self.tasks.into_iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Sized(usize);
    impl BatchTask for Sized {
        fn size(&self) -> usize {
            self.0
        }
    }

    #[test]
    fn batch_accumulates_size() {
        let mut b = Batch::new(42);
        assert!(b.is_empty());
        b.push(Sized(3));
        b.push(Sized(5));
        assert_eq!(b.len(), 2);
        assert_eq!(b.size(), 8);
        assert_eq!(b.opened_at_nanos(), 42);
    }

    #[test]
    fn into_tasks_preserves_order() {
        let mut b = Batch::new(0);
        for i in 0..5 {
            b.push(Sized(i));
        }
        let sizes: Vec<usize> = b.into_tasks().iter().map(|t| t.0).collect();
        assert_eq!(sizes, vec![0, 1, 2, 3, 4]);
    }
}
