//! Inter-request batching (paper §2.2.1).
//!
//! "The key is to combine many inference requests into a single merged
//! request … managed carefully to avoid unduly hurting latency."
//!
//! * [`batch`] — the templatized primitives: [`batch::BatchTask`],
//!   [`batch::Batch`].
//! * [`scheduler`] — [`scheduler::SharedBatchScheduler`]: dynamic
//!   per-servable **lanes** (weighted round-robin ready list, targeted
//!   `notify_one` wakeups, optional per-lane dedicated worker threads)
//!   onto a shared pool of device threads, with `max_batch_size`,
//!   `batch_timeout` and `max_enqueued` backpressure.
//! * [`padding`] — pad merged batches up to `allowed_batch_sizes`
//!   (fixed-shape accelerator executables).
//! * [`splitter`] — split oversized requests across batches.
//! * [`session`] — the paper's wrapper (1): a `Session`-like facade that
//!   concatenates tensor inputs of concurrent `run()` calls and splits
//!   the merged outputs back per caller.

pub mod batch;
pub mod padding;
pub mod scheduler;
pub mod session;
pub mod splitter;
