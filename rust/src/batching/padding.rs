//! Batch padding to `allowed_batch_sizes`.
//!
//! Accelerator executables are compiled for fixed shapes; the AOT layer
//! exports one HLO module per allowed batch size (1, 4, 16, 64 by
//! default) and the batcher pads each merged batch up to the nearest
//! allowed size. This trades a bounded amount of wasted compute for
//! avoiding recompilation — exactly what TPU serving does.

/// Smallest allowed size >= `n`, or `None` if `n` exceeds the largest.
pub fn pad_to_allowed(n: usize, allowed: &[usize]) -> Option<usize> {
    allowed.iter().copied().filter(|&a| a >= n).min()
}

/// Fraction of padded-batch rows that are padding (wasted compute).
pub fn padding_waste(n: usize, allowed: &[usize]) -> Option<f64> {
    pad_to_allowed(n, allowed).map(|p| (p - n) as f64 / p as f64)
}

/// Expected waste over a batch-size distribution (ablation metric for
/// choosing `allowed_batch_sizes`; see benches/bench_batching.rs).
pub fn expected_waste(batch_size_counts: &[(usize, u64)], allowed: &[usize]) -> f64 {
    let mut waste = 0.0;
    let mut total = 0u64;
    for &(n, count) in batch_size_counts {
        if let Some(w) = padding_waste(n, allowed) {
            waste += w * count as f64;
            total += count;
        }
    }
    if total == 0 {
        0.0
    } else {
        waste / total as f64
    }
}

/// Validate an allowed-size ladder: ascending, unique, non-empty, and
/// the last entry must equal `max_batch_size` so every admissible batch
/// has a target.
pub fn validate_allowed(allowed: &[usize], max_batch_size: usize) -> anyhow::Result<()> {
    if allowed.is_empty() {
        anyhow::bail!("allowed_batch_sizes is empty");
    }
    if !allowed.windows(2).all(|w| w[0] < w[1]) {
        anyhow::bail!("allowed_batch_sizes must be strictly ascending: {allowed:?}");
    }
    if *allowed.last().unwrap() != max_batch_size {
        anyhow::bail!(
            "last allowed batch size {} != max_batch_size {max_batch_size}",
            allowed.last().unwrap()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    const ALLOWED: &[usize] = &[1, 4, 16, 64];

    #[test]
    fn pads_up() {
        assert_eq!(pad_to_allowed(1, ALLOWED), Some(1));
        assert_eq!(pad_to_allowed(2, ALLOWED), Some(4));
        assert_eq!(pad_to_allowed(4, ALLOWED), Some(4));
        assert_eq!(pad_to_allowed(17, ALLOWED), Some(64));
        assert_eq!(pad_to_allowed(65, ALLOWED), None);
        assert_eq!(pad_to_allowed(0, ALLOWED), Some(1));
    }

    #[test]
    fn waste_math() {
        assert_eq!(padding_waste(4, ALLOWED), Some(0.0));
        assert_eq!(padding_waste(2, ALLOWED), Some(0.5));
        assert_eq!(padding_waste(48, ALLOWED), Some(0.25));
    }

    #[test]
    fn expected_waste_weighted() {
        // Half the batches size 4 (no waste), half size 2 (50% waste).
        let w = expected_waste(&[(4, 100), (2, 100)], ALLOWED);
        assert!((w - 0.25).abs() < 1e-9);
        assert_eq!(expected_waste(&[], ALLOWED), 0.0);
    }

    #[test]
    fn validation() {
        assert!(validate_allowed(ALLOWED, 64).is_ok());
        assert!(validate_allowed(&[], 64).is_err());
        assert!(validate_allowed(&[4, 1], 4).is_err());
        assert!(validate_allowed(&[1, 4], 8).is_err());
        assert!(validate_allowed(&[4, 4], 4).is_err());
    }

    #[test]
    fn pad_is_minimal_and_sufficient() {
        forall::<u64, _>("padding minimal", |n| {
            let n = (*n % 100) as usize;
            match pad_to_allowed(n, ALLOWED) {
                Some(p) => {
                    p >= n
                        && ALLOWED.contains(&p)
                        && ALLOWED.iter().all(|&a| a < n || a >= p)
                }
                None => n > *ALLOWED.last().unwrap(),
            }
        });
    }
}
