//! [`SharedBatchScheduler`]: dynamic per-servable queues feeding a
//! shared pool of device threads, round-robin (§2.2.1).
//!
//! "The core library supports multiple batching queues, to batch
//! requests for multiple servables or versions separately, and schedule
//! them in a round-robin fashion onto a single shared device e.g. GPU.
//! The set of queues can be dynamic, added and removed as servable
//! versions come and go."
//!
//! Batch close conditions: summed task size reaching `max_batch_size`,
//! or the open batch ageing past `batch_timeout` (the latency guard).
//! Backpressure: a queue holds at most `max_enqueued_batches` closed
//! batches; beyond that, `enqueue` rejects — callers shed load instead
//! of growing an unbounded queue.

use super::batch::{Batch, BatchTask};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduler-wide options.
#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Shared device threads executing batches (≈ accelerator streams).
    pub num_batch_threads: usize,
    pub name: String,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { num_batch_threads: 2, name: "batcher".to_string() }
    }
}

/// Per-queue options.
#[derive(Debug, Clone)]
pub struct QueueOptions {
    /// Maximum summed task size of one batch.
    pub max_batch_size: usize,
    /// Age at which a non-full open batch is closed anyway.
    pub batch_timeout: Duration,
    /// Closed-but-unprocessed batch limit (backpressure).
    pub max_enqueued_batches: usize,
}

impl Default for QueueOptions {
    fn default() -> Self {
        QueueOptions {
            max_batch_size: 16,
            batch_timeout: Duration::from_millis(2),
            max_enqueued_batches: 64,
        }
    }
}

/// Why an enqueue was rejected (the task is returned to the caller).
#[derive(Debug)]
pub enum EnqueueError<T> {
    /// Task size exceeds `max_batch_size` (consider the splitter).
    TaskTooLarge(T),
    /// Queue is at `max_enqueued_batches` (shed load).
    QueueFull(T),
    /// Queue was removed.
    QueueClosed(T),
}

impl<T> std::fmt::Display for EnqueueError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnqueueError::TaskTooLarge(_) => write!(f, "task larger than max_batch_size"),
            EnqueueError::QueueFull(_) => write!(f, "queue full (backpressure)"),
            EnqueueError::QueueClosed(_) => write!(f, "queue closed"),
        }
    }
}

type ProcessFn<T> = Arc<dyn Fn(Batch<T>) + Send + Sync>;

struct QueueInner<T: BatchTask> {
    open: Option<Batch<T>>,
    closed: VecDeque<Batch<T>>,
}

struct QueueState<T: BatchTask> {
    name: String,
    opts: QueueOptions,
    inner: Mutex<QueueInner<T>>,
    process: ProcessFn<T>,
    removed: AtomicBool,
    batches_processed: AtomicU64,
    tasks_processed: AtomicU64,
}

impl<T: BatchTask> QueueState<T> {
    /// Close the open batch if full or expired. Returns true if a batch
    /// became available.
    fn maybe_close_open(&self, inner: &mut QueueInner<T>, now_nanos: u64) -> bool {
        let close = match &inner.open {
            Some(open) => {
                open.size() >= self.opts.max_batch_size
                    || now_nanos.saturating_sub(open.opened_at_nanos())
                        >= self.opts.batch_timeout.as_nanos() as u64
            }
            None => false,
        };
        if close {
            inner.closed.push_back(inner.open.take().unwrap());
        }
        close
    }

    /// Next deadline (nanos) at which the open batch expires.
    fn open_deadline(&self, inner: &QueueInner<T>) -> Option<u64> {
        inner
            .open
            .as_ref()
            .map(|b| b.opened_at_nanos() + self.opts.batch_timeout.as_nanos() as u64)
    }
}

struct Shared<T: BatchTask> {
    queues: Mutex<Vec<Arc<QueueState<T>>>>,
    work: Condvar,
    work_lock: Mutex<()>,
    rr: AtomicUsize,
    shutdown: AtomicBool,
    epoch: Instant,
}

impl<T: BatchTask> Shared<T> {
    fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn signal(&self) {
        let _g = self.work_lock.lock().unwrap();
        self.work.notify_all();
    }
}

/// Handle to one queue; dropping it removes the queue (pending batches
/// still drain). Created via [`SharedBatchScheduler::add_queue`].
pub struct BatchQueue<T: BatchTask> {
    state: Arc<QueueState<T>>,
    shared: Arc<Shared<T>>,
}

impl<T: BatchTask> BatchQueue<T> {
    /// Add `task` to the queue. On success the task will be processed
    /// as part of a future batch by a scheduler thread.
    pub fn enqueue(&self, task: T) -> Result<(), EnqueueError<T>> {
        if self.state.removed.load(Ordering::SeqCst) {
            return Err(EnqueueError::QueueClosed(task));
        }
        if task.size() > self.state.opts.max_batch_size {
            return Err(EnqueueError::TaskTooLarge(task));
        }
        let now = self.shared.now_nanos();
        {
            let mut inner = self.state.inner.lock().unwrap();
            // Close a full/expired open batch first so the size check
            // below sees fresh state.
            self.state.maybe_close_open(&mut inner, now);
            // If the task doesn't fit the current open batch, close it.
            if let Some(open) = &inner.open {
                if open.size() + task.size() > self.state.opts.max_batch_size {
                    let b = inner.open.take().unwrap();
                    inner.closed.push_back(b);
                }
            }
            if inner.closed.len() >= self.state.opts.max_enqueued_batches {
                return Err(EnqueueError::QueueFull(task));
            }
            let open = inner.open.get_or_insert_with(|| Batch::new(now));
            open.push(task);
            if open.size() >= self.state.opts.max_batch_size {
                let b = inner.open.take().unwrap();
                inner.closed.push_back(b);
            }
        }
        self.shared.signal();
        Ok(())
    }

    /// Tasks sitting in the queue (open + closed), for monitoring.
    pub fn pending_tasks(&self) -> usize {
        let inner = self.state.inner.lock().unwrap();
        inner.open.as_ref().map_or(0, |b| b.len())
            + inner.closed.iter().map(|b| b.len()).sum::<usize>()
    }

    pub fn batches_processed(&self) -> u64 {
        self.state.batches_processed.load(Ordering::Relaxed)
    }

    pub fn tasks_processed(&self) -> u64 {
        self.state.tasks_processed.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &str {
        &self.state.name
    }

    /// Mark the queue removed without waiting for the handle to drop:
    /// further enqueues fail with [`EnqueueError::QueueClosed`], the
    /// open batch flushes eagerly (workers process removed queues'
    /// pending work immediately instead of waiting out the batch
    /// timeout), and the queue disappears once drained. Idempotent.
    /// The serving layer's unload path calls this so teardown never
    /// blocks on request threads that still hold session references.
    pub fn close(&self) {
        self.state.removed.store(true, Ordering::SeqCst);
        self.shared.signal();
    }
}

impl<T: BatchTask> Drop for BatchQueue<T> {
    fn drop(&mut self) {
        self.close();
    }
}

/// The shared scheduler. Owns the device threads.
pub struct SharedBatchScheduler<T: BatchTask> {
    shared: Arc<Shared<T>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<T: BatchTask> SharedBatchScheduler<T> {
    pub fn new(options: SchedulerOptions) -> Self {
        let shared = Arc::new(Shared {
            queues: Mutex::new(Vec::new()),
            work: Condvar::new(),
            work_lock: Mutex::new(()),
            rr: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            epoch: Instant::now(),
        });
        let workers = (0..options.num_batch_threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("{}-dev-{i}", options.name))
                    .spawn(move || Self::worker_loop(shared))
                    .expect("spawn batch worker")
            })
            .collect();
        SharedBatchScheduler { shared, workers }
    }

    /// Create a queue whose batches are handed to `process` on a device
    /// thread. Queues are dynamic: drop the handle to remove.
    pub fn add_queue<F>(&self, name: &str, opts: QueueOptions, process: F) -> BatchQueue<T>
    where
        F: Fn(Batch<T>) + Send + Sync + 'static,
    {
        assert!(opts.max_batch_size > 0, "max_batch_size must be positive");
        let state = Arc::new(QueueState {
            name: name.to_string(),
            opts,
            inner: Mutex::new(QueueInner { open: None, closed: VecDeque::new() }),
            process: Arc::new(process),
            removed: AtomicBool::new(false),
            batches_processed: AtomicU64::new(0),
            tasks_processed: AtomicU64::new(0),
        });
        self.shared.queues.lock().unwrap().push(Arc::clone(&state));
        BatchQueue { state, shared: Arc::clone(&self.shared) }
    }

    fn worker_loop(shared: Arc<Shared<T>>) {
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            let mut next_deadline: Option<u64> = None;
            let mut picked: Option<(Arc<QueueState<T>>, Batch<T>)> = None;
            {
                let mut queues = shared.queues.lock().unwrap();
                // Prune fully-drained removed queues.
                queues.retain(|q| {
                    !q.removed.load(Ordering::SeqCst) || {
                        let inner = q.inner.lock().unwrap();
                        inner.open.is_some() || !inner.closed.is_empty()
                    }
                });
                let n = queues.len();
                if n > 0 {
                    let start = shared.rr.fetch_add(1, Ordering::Relaxed) % n;
                    let now = shared.now_nanos();
                    // Round-robin scan for the next ready batch.
                    for off in 0..n {
                        let q = &queues[(start + off) % n];
                        let mut inner = q.inner.lock().unwrap();
                        q.maybe_close_open(&mut inner, now);
                        // Removed queues flush their open batch eagerly.
                        if q.removed.load(Ordering::SeqCst) {
                            if let Some(b) = inner.open.take() {
                                inner.closed.push_back(b);
                            }
                        }
                        if let Some(batch) = inner.closed.pop_front() {
                            picked = Some((Arc::clone(q), batch));
                            break;
                        }
                        if let Some(d) = q.open_deadline(&inner) {
                            next_deadline =
                                Some(next_deadline.map_or(d, |nd: u64| nd.min(d)));
                        }
                    }
                }
            }
            match picked {
                Some((q, batch)) => {
                    // Execute outside all locks: this is the "device".
                    q.batches_processed.fetch_add(1, Ordering::Relaxed);
                    q.tasks_processed.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    (q.process)(batch);
                }
                None => {
                    // Sleep until the nearest open-batch deadline (or a
                    // signal), capped so shutdown is prompt.
                    let now = shared.now_nanos();
                    let wait = match next_deadline {
                        Some(d) if d > now => Duration::from_nanos((d - now).min(5_000_000)),
                        Some(_) => continue, // already expired: rescan
                        None => Duration::from_millis(5),
                    };
                    let g = shared.work_lock.lock().unwrap();
                    let _ = shared.work.wait_timeout(g, wait).unwrap();
                }
            }
        }
    }

    /// Block until all queues are empty (tests/benches).
    pub fn quiesce(&self) {
        loop {
            let empty = {
                let queues = self.shared.queues.lock().unwrap();
                queues.iter().all(|q| {
                    let inner = q.inner.lock().unwrap();
                    inner.open.is_none() && inner.closed.is_empty()
                })
            };
            if empty {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl<T: BatchTask> Drop for SharedBatchScheduler<T> {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.signal();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[derive(Debug)]
    struct Task {
        size: usize,
        tag: usize,
    }

    impl BatchTask for Task {
        fn size(&self) -> usize {
            self.size
        }
    }

    fn collector() -> (
        impl Fn(Batch<Task>) + Send + Sync + 'static,
        mpsc::Receiver<Vec<(usize, usize)>>,
    ) {
        let (tx, rx) = mpsc::channel();
        (
            move |b: Batch<Task>| {
                let v: Vec<(usize, usize)> =
                    b.tasks().iter().map(|t| (t.tag, t.size)).collect();
                let _ = tx.send(v);
            },
            rx,
        )
    }

    #[test]
    fn full_batch_processes_immediately() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 4,
                batch_timeout: Duration::from_secs(100), // never by timeout
                max_enqueued_batches: 8,
            },
            f,
        );
        for tag in 0..4 {
            q.enqueue(Task { size: 1, tag }).unwrap();
        }
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 100,
                batch_timeout: Duration::from_millis(5),
                max_enqueued_batches: 8,
            },
            f,
        );
        q.enqueue(Task { size: 1, tag: 7 }).unwrap();
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch, vec![(7, 1)]);
    }

    #[test]
    fn size_units_respected() {
        // max_batch_size is in task-size units, not task count.
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(2),
                max_enqueued_batches: 8,
            },
            f,
        );
        q.enqueue(Task { size: 5, tag: 0 }).unwrap();
        q.enqueue(Task { size: 5, tag: 1 }).unwrap(); // doesn't fit with 0
        let b0 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let b1 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(b0, vec![(0, 5)]);
        assert_eq!(b1, vec![(1, 5)]);
    }

    #[test]
    fn oversized_task_rejected() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, _rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions { max_batch_size: 4, ..Default::default() },
            f,
        );
        match q.enqueue(Task { size: 10, tag: 0 }) {
            Err(EnqueueError::TaskTooLarge(t)) => assert_eq!(t.tag, 0),
            other => panic!("expected TaskTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        // Block the single device thread with a slow first batch.
        let (slow_tx, slow_rx) = mpsc::channel::<()>();
        let slow_rx = Mutex::new(slow_rx);
        let blocker = sched.add_queue(
            "blocker",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::from_millis(0),
                max_enqueued_batches: 4,
            },
            move |_b| {
                let _ = slow_rx.lock().unwrap().recv();
            },
        );
        blocker.enqueue(Task { size: 1, tag: 0 }).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // device now blocked

        let (f, _rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 1, // every task closes a batch
                batch_timeout: Duration::from_millis(0),
                max_enqueued_batches: 2,
            },
            f,
        );
        let mut rejected = false;
        for tag in 0..10 {
            if matches!(
                q.enqueue(Task { size: 1, tag }),
                Err(EnqueueError::QueueFull(_))
            ) {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "backpressure never kicked in");
        let _ = slow_tx.send(());
        let _ = slow_tx.send(());
    }

    #[test]
    fn round_robin_across_queues() {
        // One device thread, two queues with pre-loaded batches: the
        // processing order must interleave.
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let mk = |label: &'static str, order: Arc<Mutex<Vec<&'static str>>>| {
            move |_b: Batch<Task>| {
                order.lock().unwrap().push(label);
                std::thread::sleep(Duration::from_millis(1));
            }
        };
        let qa = sched.add_queue(
            "a",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
            },
            mk("a", Arc::clone(&order)),
        );
        let qb = sched.add_queue(
            "b",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
            },
            mk("b", Arc::clone(&order)),
        );
        for tag in 0..8 {
            qa.enqueue(Task { size: 1, tag }).unwrap();
            qb.enqueue(Task { size: 1, tag }).unwrap();
        }
        sched.quiesce();
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 16);
        // Interleaving check: no long runs of one queue.
        let max_run = order
            .windows(4)
            .map(|w| w.iter().filter(|&&l| l == w[0]).count())
            .max()
            .unwrap();
        assert!(max_run < 4, "not interleaved: {order:?}");
        assert_eq!(qa.tasks_processed(), 8);
        assert_eq!(qb.tasks_processed(), 8);
    }

    #[test]
    fn dropped_queue_drains_then_disappears() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 10,
                batch_timeout: Duration::from_secs(100),
                max_enqueued_batches: 8,
            },
            f,
        );
        q.enqueue(Task { size: 1, tag: 1 }).unwrap();
        drop(q); // open batch must still flush
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch, vec![(1, 1)]);
    }

    #[test]
    fn enqueue_after_drop_fails() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, _rx) = collector();
        let q = sched.add_queue("q", QueueOptions::default(), f);
        let state = Arc::clone(&q.state);
        let shared = Arc::clone(&q.shared);
        drop(q);
        let q2 = BatchQueue { state, shared };
        assert!(matches!(
            q2.enqueue(Task { size: 1, tag: 0 }),
            Err(EnqueueError::QueueClosed(_))
        ));
    }

    #[test]
    fn many_tasks_all_processed_exactly_once() {
        let sched = SharedBatchScheduler::<Task>::new(SchedulerOptions {
            num_batch_threads: 4,
            ..Default::default()
        });
        let seen = Arc::new(Mutex::new(std::collections::HashMap::<usize, usize>::new()));
        let s2 = Arc::clone(&seen);
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 7,
                batch_timeout: Duration::from_micros(200),
                max_enqueued_batches: 1_000_000,
            },
            move |b| {
                let mut m = s2.lock().unwrap();
                for t in b.tasks() {
                    *m.entry(t.tag).or_default() += 1;
                }
            },
        );
        const N: usize = 5000;
        for tag in 0..N {
            q.enqueue(Task { size: 1, tag }).unwrap();
        }
        sched.quiesce();
        let m = seen.lock().unwrap();
        assert_eq!(m.len(), N);
        assert!(m.values().all(|&c| c == 1), "duplicate processing");
    }
}
