//! [`SharedBatchScheduler`]: dynamic per-servable **lanes** feeding a
//! shared pool of device threads (§2.2.1), with isolation guarantees a
//! naive shared queue lacks.
//!
//! "The core library supports multiple batching queues, to batch
//! requests for multiple servables or versions separately, and schedule
//! them in a round-robin fashion onto a single shared device e.g. GPU.
//! The set of queues can be dynamic, added and removed as servable
//! versions come and go."
//!
//! ## Lanes and the ready list
//!
//! Each queue is an isolated *lane*: its open/closed batches live
//! behind its own mutex, and lanes with work sit on a shared **ready
//! list** with **at most one entry per lane**. A worker pops the
//! front lane, takes up to [`QueueOptions::weight`] closed batches,
//! and — before executing them — hands the lane's entry back to the
//! *back* of the list if a backlog remains. That gives weighted
//! round-robin fairness (a lane with 50 queued batches cedes the
//! device after `weight` picks, so another model's single batch waits
//! behind at most one pick per lane, never behind the whole backlog)
//! while still letting several workers drain one lane's backlog
//! concurrently (the re-enqueue happens before the device call).
//!
//! Enqueues signal with **targeted `notify_one` wakeups** — one per
//! newly closed batch, plus one timer-rearm when a fresh open batch
//! creates a deadline — so an enqueue storm wakes exactly as many
//! workers as there are batches to run instead of stampeding every
//! idle worker over the queue mutex (the thundering-herd fix).
//!
//! Lanes with [`QueueOptions::dedicated_threads`] > 0 get a **private
//! worker set**: their batches never touch the shared ready list, so a
//! latency-critical model keeps its own device threads no matter how
//! saturated the shared lanes are (the multi-tenant head-of-line fix).
//!
//! Batch close conditions: summed task size reaching `max_batch_size`,
//! or the open batch ageing past `batch_timeout` (the latency guard).
//! Backpressure: a queue holds at most `max_enqueued_batches` closed
//! batches; beyond that, `enqueue` rejects — callers shed load instead
//! of growing an unbounded queue.

use super::batch::{Batch, BatchTask};
use crate::util::metrics::Gauge;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Scheduler-wide options.
#[derive(Debug, Clone)]
pub struct SchedulerOptions {
    /// Shared device threads executing batches (≈ accelerator streams).
    pub num_batch_threads: usize,
    pub name: String,
}

impl Default for SchedulerOptions {
    fn default() -> Self {
        SchedulerOptions { num_batch_threads: 2, name: "batcher".to_string() }
    }
}

/// Per-lane options.
#[derive(Debug, Clone)]
pub struct QueueOptions {
    /// Maximum summed task size of one batch.
    pub max_batch_size: usize,
    /// Age at which a non-full open batch is closed anyway.
    pub batch_timeout: Duration,
    /// Closed-but-unprocessed batch limit (backpressure).
    pub max_enqueued_batches: usize,
    /// Closed batches a shared worker may take per ready-list pick
    /// (weighted round-robin share; 0 behaves as 1).
    pub weight: usize,
    /// Private worker threads for this lane. 0 = the shared pool;
    /// > 0 isolates the lane completely from shared-lane backlogs.
    pub dedicated_threads: usize,
    /// Optional gauge tracking task rows currently queued in this lane
    /// (`batch.{model}.lane_depth` in the serving registry).
    pub depth_gauge: Option<Arc<Gauge>>,
}

impl Default for QueueOptions {
    fn default() -> Self {
        QueueOptions {
            max_batch_size: 16,
            batch_timeout: Duration::from_millis(2),
            max_enqueued_batches: 64,
            weight: 1,
            dedicated_threads: 0,
            depth_gauge: None,
        }
    }
}

/// Why an enqueue was rejected (the task is returned to the caller).
#[derive(Debug)]
pub enum EnqueueError<T> {
    /// Task size exceeds `max_batch_size` (consider the splitter).
    TaskTooLarge(T),
    /// Queue is at `max_enqueued_batches` (shed load).
    QueueFull(T),
    /// Queue was removed.
    QueueClosed(T),
}

impl<T> std::fmt::Display for EnqueueError<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnqueueError::TaskTooLarge(_) => write!(f, "task larger than max_batch_size"),
            EnqueueError::QueueFull(_) => write!(f, "queue full (backpressure)"),
            EnqueueError::QueueClosed(_) => write!(f, "queue closed"),
        }
    }
}

type ProcessFn<T> = Arc<dyn Fn(Batch<T>) + Send + Sync>;

/// Pop the closed batch with the nearest member deadline (EDF within
/// the lane). Deadline-free batches rank after any deadline; among
/// ties — and in the all-deadline-free case — the oldest (front) batch
/// wins, so lanes without deadlines keep exact FIFO arrival order.
fn pop_earliest_deadline<T: BatchTask>(
    closed: &mut VecDeque<Batch<T>>,
) -> Option<Batch<T>> {
    let mut best = 0usize;
    let mut best_deadline = closed.front()?.earliest_deadline();
    for (i, b) in closed.iter().enumerate().skip(1) {
        match (best_deadline, b.earliest_deadline()) {
            (Some(bd), Some(d)) if d < bd => {
                best = i;
                best_deadline = Some(d);
            }
            (None, Some(d)) => {
                best = i;
                best_deadline = Some(d);
            }
            _ => {}
        }
    }
    if best == 0 {
        closed.pop_front()
    } else {
        closed.remove(best)
    }
}

struct QueueInner<T: BatchTask> {
    open: Option<Batch<T>>,
    closed: VecDeque<Batch<T>>,
}

struct QueueState<T: BatchTask> {
    name: String,
    opts: QueueOptions,
    inner: Mutex<QueueInner<T>>,
    /// Wakes this lane's dedicated workers (paired with `inner`).
    /// Unused for shared lanes.
    cv: Condvar,
    process: ProcessFn<T>,
    removed: AtomicBool,
    /// True while the lane holds a ready-list entry (on the list or
    /// popped by a worker that will put it back / clear the flag).
    /// Guarantees at most one entry per lane.
    enlisted: AtomicBool,
    batches_processed: AtomicU64,
    tasks_processed: AtomicU64,
}

impl<T: BatchTask> QueueState<T> {
    fn dedicated(&self) -> bool {
        self.opts.dedicated_threads > 0
    }

    /// Close the open batch if full or expired. Returns true if a batch
    /// became available.
    fn maybe_close_open(&self, inner: &mut QueueInner<T>, now_nanos: u64) -> bool {
        let close = match &inner.open {
            Some(open) => {
                open.size() >= self.opts.max_batch_size
                    || now_nanos.saturating_sub(open.opened_at_nanos())
                        >= self.opts.batch_timeout.as_nanos() as u64
            }
            None => false,
        };
        if close {
            inner.closed.push_back(inner.open.take().unwrap());
        }
        close
    }

    /// Next deadline (nanos) at which the open batch expires.
    fn open_deadline(&self, inner: &QueueInner<T>) -> Option<u64> {
        inner
            .open
            .as_ref()
            .map(|b| b.opened_at_nanos() + self.opts.batch_timeout.as_nanos() as u64)
    }

    /// Removed lanes drain eagerly: move the open batch (if any) to
    /// the closed list so it is processed now, not at batch timeout.
    fn flush_if_removed(&self, inner: &mut QueueInner<T>) {
        if self.removed.load(Ordering::SeqCst) {
            if let Some(b) = inner.open.take() {
                inner.closed.push_back(b);
            }
        }
    }

    /// Take a batch off the lane, account it, and run the device call.
    fn run_batch(&self, batch: Batch<T>) {
        if let Some(g) = &self.opts.depth_gauge {
            g.add(-(batch.size() as i64));
        }
        self.batches_processed.fetch_add(1, Ordering::Relaxed);
        self.tasks_processed.fetch_add(batch.len() as u64, Ordering::Relaxed);
        (self.process)(batch);
    }
}

struct Shared<T: BatchTask> {
    /// Registry of every lane (deadline scans, pruning, quiesce).
    /// Touched by idle workers only — never on the enqueue path.
    queues: Mutex<Vec<Arc<QueueState<T>>>>,
    /// Shared lanes with closed batches awaiting a worker; at most one
    /// entry per lane (`QueueState::enlisted`).
    ready: Mutex<VecDeque<Arc<QueueState<T>>>>,
    /// Paired with `ready`.
    work: Condvar,
    /// Set (under the `ready` lock) when open-batch deadlines changed
    /// and a sleeping worker should recompute its wait.
    timer_dirty: AtomicBool,
    /// Nearest open-batch deadline (nanos) across shared lanes,
    /// `u64::MAX` = none. Lets saturated workers honor batch timeouts
    /// with one atomic load per pick instead of a registry scan.
    next_open_deadline: AtomicU64,
    shutdown: AtomicBool,
    epoch: Instant,
}

impl<T: BatchTask> Shared<T> {
    fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Put `q` on the shared ready list (if not already there) and wake
    /// exactly one worker; dedicated lanes wake one of their private
    /// workers instead. This is the targeted per-batch wakeup — never
    /// a broadcast.
    fn enlist(&self, q: &Arc<QueueState<T>>) {
        if q.dedicated() {
            q.cv.notify_one();
            return;
        }
        if !q.enlisted.swap(true, Ordering::AcqRel) {
            let mut ready = self.ready.lock().unwrap();
            ready.push_back(Arc::clone(q));
            drop(ready);
            self.work.notify_one();
        }
    }

    /// A fresh open batch created a (possibly nearer) deadline: make
    /// one sleeping worker recompute its wait. Dedicated lanes rearm
    /// their own workers.
    fn rearm_timer(&self, q: &Arc<QueueState<T>>) {
        if q.dedicated() {
            q.cv.notify_one();
            return;
        }
        let _g = self.ready.lock().unwrap();
        self.timer_dirty.store(true, Ordering::Release);
        self.work.notify_one();
    }
}

/// Handle to one lane; dropping it removes the lane (pending batches
/// still drain). Created via [`SharedBatchScheduler::add_queue`].
pub struct BatchQueue<T: BatchTask> {
    state: Arc<QueueState<T>>,
    shared: Arc<Shared<T>>,
}

impl<T: BatchTask> BatchQueue<T> {
    /// Add `task` to the queue. On success the task will be processed
    /// as part of a future batch by a scheduler thread.
    pub fn enqueue(&self, task: T) -> Result<(), EnqueueError<T>> {
        if self.state.removed.load(Ordering::SeqCst) {
            return Err(EnqueueError::QueueClosed(task));
        }
        if task.size() > self.state.opts.max_batch_size {
            return Err(EnqueueError::TaskTooLarge(task));
        }
        let now = self.shared.now_nanos();
        let rows = task.size();
        let (batch_closed, batch_opened) = {
            let mut inner = self.state.inner.lock().unwrap();
            // Authoritative removal check, under the lane lock: close()
            // flushes under this same lock, so a task admitted here is
            // guaranteed to be seen by the drain (the lock-free check
            // above is only a fast path — without this one, a straggler
            // could push into a lane whose workers already drained and
            // exited, and hang its caller forever).
            if self.state.removed.load(Ordering::SeqCst) {
                return Err(EnqueueError::QueueClosed(task));
            }
            // Close a full/expired open batch first so the size check
            // below sees fresh state.
            let mut closed_any = self.state.maybe_close_open(&mut inner, now);
            // If the task doesn't fit the current open batch, close it.
            if let Some(open) = &inner.open {
                if open.size() + task.size() > self.state.opts.max_batch_size {
                    let b = inner.open.take().unwrap();
                    inner.closed.push_back(b);
                    closed_any = true;
                }
            }
            if inner.closed.len() >= self.state.opts.max_enqueued_batches {
                if closed_any && self.state.dedicated() {
                    self.state.cv.notify_one();
                }
                drop(inner);
                // Batches we closed on the way in still need a worker
                // even though this task was shed.
                if closed_any && !self.state.dedicated() {
                    self.shared.enlist(&self.state);
                }
                return Err(EnqueueError::QueueFull(task));
            }
            let opened = inner.open.is_none();
            let open = inner.open.get_or_insert_with(|| Batch::new(now));
            open.push(task);
            if open.size() >= self.state.opts.max_batch_size {
                let b = inner.open.take().unwrap();
                inner.closed.push_back(b);
                closed_any = true;
            }
            // Gauge add under the lane lock, before the task is
            // visible to any worker — run_batch's decrement can never
            // land first, so the gauge never reads negative.
            if let Some(g) = &self.state.opts.depth_gauge {
                g.add(rows as i64);
            }
            // Dedicated lanes notify under the lane lock: a private
            // worker between its emptiness check and its wait cannot
            // miss the wakeup.
            if self.state.dedicated() && (closed_any || opened) {
                self.state.cv.notify_one();
            }
            (closed_any, opened)
        };
        if !self.state.dedicated() {
            if batch_opened {
                // Register the new open batch's deadline so even fully
                // saturated workers (which never idle-scan) see it.
                self.shared.next_open_deadline.fetch_min(
                    now + self.state.opts.batch_timeout.as_nanos() as u64,
                    Ordering::Relaxed,
                );
            }
            if batch_closed {
                // Targeted wakeup: one worker per lane with work.
                self.shared.enlist(&self.state);
            } else if batch_opened {
                // No batch to run yet, but a deadline now exists.
                self.shared.rearm_timer(&self.state);
            }
        }
        Ok(())
    }

    /// Tasks sitting in the queue (open + closed), for monitoring.
    pub fn pending_tasks(&self) -> usize {
        let inner = self.state.inner.lock().unwrap();
        inner.open.as_ref().map_or(0, |b| b.len())
            + inner.closed.iter().map(|b| b.len()).sum::<usize>()
    }

    pub fn batches_processed(&self) -> u64 {
        self.state.batches_processed.load(Ordering::Relaxed)
    }

    pub fn tasks_processed(&self) -> u64 {
        self.state.tasks_processed.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &str {
        &self.state.name
    }

    /// Mark the queue removed without waiting for the handle to drop:
    /// further enqueues fail with [`EnqueueError::QueueClosed`], the
    /// open batch flushes eagerly (workers process removed queues'
    /// pending work immediately instead of waiting out the batch
    /// timeout), and the queue disappears once drained. Idempotent.
    /// The serving layer's unload path calls this so teardown never
    /// blocks on request threads that still hold session references.
    pub fn close(&self) {
        self.state.removed.store(true, Ordering::SeqCst);
        let flushed = {
            let mut inner = self.state.inner.lock().unwrap();
            self.state.flush_if_removed(&mut inner);
            if self.state.dedicated() {
                // Under the lane lock (no missed wakeup): private
                // workers must observe the removal — to drain the
                // flush, or to exit when nothing is left.
                self.state.cv.notify_all();
            }
            !inner.closed.is_empty()
        };
        if flushed && !self.state.dedicated() {
            self.shared.enlist(&self.state);
        }
    }
}

impl<T: BatchTask> Drop for BatchQueue<T> {
    fn drop(&mut self) {
        self.close();
    }
}

/// The shared scheduler. Owns the device threads (shared pool +
/// per-lane dedicated workers).
pub struct SharedBatchScheduler<T: BatchTask> {
    shared: Arc<Shared<T>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Private workers of dedicated lanes (joined on drop alongside
    /// the shared pool).
    dedicated_workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl<T: BatchTask> SharedBatchScheduler<T> {
    pub fn new(options: SchedulerOptions) -> Self {
        let shared = Arc::new(Shared {
            queues: Mutex::new(Vec::new()),
            ready: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            timer_dirty: AtomicBool::new(false),
            next_open_deadline: AtomicU64::new(u64::MAX),
            shutdown: AtomicBool::new(false),
            epoch: Instant::now(),
        });
        let workers = (0..options.num_batch_threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("{}-dev-{i}", options.name))
                    .spawn(move || Self::worker_loop(shared))
                    .expect("spawn batch worker")
            })
            .collect();
        SharedBatchScheduler { shared, workers, dedicated_workers: Mutex::new(Vec::new()) }
    }

    /// Create a lane whose batches are handed to `process` on a device
    /// thread — the shared pool, or a private worker set when
    /// `opts.dedicated_threads > 0`. Lanes are dynamic: drop the
    /// handle to remove.
    pub fn add_queue<F>(&self, name: &str, opts: QueueOptions, process: F) -> BatchQueue<T>
    where
        F: Fn(Batch<T>) + Send + Sync + 'static,
    {
        assert!(opts.max_batch_size > 0, "max_batch_size must be positive");
        let dedicated_threads = opts.dedicated_threads;
        let state = Arc::new(QueueState {
            name: name.to_string(),
            opts,
            inner: Mutex::new(QueueInner { open: None, closed: VecDeque::new() }),
            cv: Condvar::new(),
            process: Arc::new(process),
            removed: AtomicBool::new(false),
            enlisted: AtomicBool::new(false),
            batches_processed: AtomicU64::new(0),
            tasks_processed: AtomicU64::new(0),
        });
        self.shared.queues.lock().unwrap().push(Arc::clone(&state));
        if dedicated_threads > 0 {
            let mut private = self.dedicated_workers.lock().unwrap();
            // Reap workers of lanes that drained and exited, so version
            // churn on dedicated-thread models doesn't accumulate dead
            // JoinHandles for the scheduler's (process-long) lifetime.
            let (done, running): (Vec<_>, Vec<_>) =
                private.drain(..).partition(|h| h.is_finished());
            *private = running;
            for h in done {
                let _ = h.join();
            }
            for i in 0..dedicated_threads {
                let shared = Arc::clone(&self.shared);
                let q = Arc::clone(&state);
                private.push(
                    std::thread::Builder::new()
                        .name(format!("{name}-lane-{i}"))
                        .spawn(move || Self::dedicated_loop(shared, q))
                        .expect("spawn dedicated lane worker"),
                );
            }
        }
        BatchQueue { state, shared: Arc::clone(&self.shared) }
    }

    /// Service one ready lane: take up to `weight` closed batches,
    /// re-enqueue the lane's entry *before* executing (so other
    /// workers can drain the same lane concurrently and other lanes
    /// rotate in behind it), then run the batches.
    fn service_lane(shared: &Arc<Shared<T>>, q: &Arc<QueueState<T>>) {
        let weight = q.opts.weight.max(1);
        let mut taken: Vec<Batch<T>> = Vec::new();
        let backlog = {
            let mut inner = q.inner.lock().unwrap();
            if q.removed.load(Ordering::SeqCst) {
                q.flush_if_removed(&mut inner);
            } else {
                q.maybe_close_open(&mut inner, shared.now_nanos());
            }
            while taken.len() < weight {
                match pop_earliest_deadline(&mut inner.closed) {
                    Some(b) => taken.push(b),
                    None => break,
                }
            }
            !inner.closed.is_empty()
        };
        if backlog {
            // Rotate: entry to the back of the list (still enlisted),
            // one more worker woken for the remaining batches.
            let mut ready = shared.ready.lock().unwrap();
            ready.push_back(Arc::clone(q));
            drop(ready);
            shared.work.notify_one();
        } else {
            q.enlisted.store(false, Ordering::Release);
            // Re-check: a batch may have closed between our pop loop
            // and the flag store; whoever loses the swap race leaves
            // enlisting to the winner.
            let refill = !q.inner.lock().unwrap().closed.is_empty();
            if refill && !q.enlisted.swap(true, Ordering::AcqRel) {
                let mut ready = shared.ready.lock().unwrap();
                ready.push_back(Arc::clone(q));
                drop(ready);
                shared.work.notify_one();
            }
        }
        for batch in taken {
            q.run_batch(batch);
        }
    }

    /// Idle pass over the lane registry: prune drained removed lanes,
    /// close expired open batches (enlisting their lanes), and report
    /// the nearest open-batch deadline. Dedicated lanes keep their own
    /// time and are only pruned here.
    fn idle_scan(shared: &Arc<Shared<T>>) -> Option<u64> {
        let now = shared.now_nanos();
        let mut next_deadline: Option<u64> = None;
        let mut expired: Vec<Arc<QueueState<T>>> = Vec::new();
        {
            let mut queues = shared.queues.lock().unwrap();
            queues.retain(|q| {
                !q.removed.load(Ordering::SeqCst) || {
                    let inner = q.inner.lock().unwrap();
                    inner.open.is_some() || !inner.closed.is_empty()
                }
            });
            for q in queues.iter() {
                if q.dedicated() {
                    continue;
                }
                let mut inner = q.inner.lock().unwrap();
                q.maybe_close_open(&mut inner, now);
                q.flush_if_removed(&mut inner);
                let has_closed = !inner.closed.is_empty();
                let deadline = q.open_deadline(&inner);
                drop(inner);
                if has_closed {
                    expired.push(Arc::clone(q));
                }
                if let Some(d) = deadline {
                    next_deadline = Some(next_deadline.map_or(d, |nd: u64| nd.min(d)));
                }
            }
        }
        // Enlist outside the registry lock (enlist takes the ready
        // lock). Already-enlisted lanes are skipped by the flag.
        for q in expired {
            shared.enlist(&q);
        }
        next_deadline
    }

    /// Recompute the nearest-deadline atomic from a full scan. The
    /// MAX-store happens first so a concurrent enqueue's `fetch_min`
    /// is never overwritten by our (possibly staler) result.
    fn refresh_deadlines(shared: &Arc<Shared<T>>) -> Option<u64> {
        shared.next_open_deadline.store(u64::MAX, Ordering::Relaxed);
        let next = Self::idle_scan(shared);
        if let Some(d) = next {
            shared.next_open_deadline.fetch_min(d, Ordering::Relaxed);
        }
        next
    }

    fn worker_loop(shared: Arc<Shared<T>>) {
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            // Ready lane? Service it (the hot path touches only the
            // ready list and that lane's mutex — never the registry).
            let lane = shared.ready.lock().unwrap().pop_front();
            if let Some(q) = lane {
                Self::service_lane(&shared, &q);
                // Saturated pools never idle: still honor other lanes'
                // batch timeouts via one atomic check per pick.
                if shared.now_nanos()
                    >= shared.next_open_deadline.load(Ordering::Relaxed)
                {
                    Self::refresh_deadlines(&shared);
                }
                continue;
            }
            // Idle: close expired batches, then sleep until the
            // nearest open-batch deadline (or a signal), capped so
            // shutdown is prompt.
            let next_deadline = Self::refresh_deadlines(&shared);
            let now = shared.now_nanos();
            let wait = match next_deadline {
                Some(d) if d > now => Duration::from_nanos((d - now).min(5_000_000)),
                Some(_) => continue, // already expired: rescan
                None => Duration::from_millis(5),
            };
            let g = shared.ready.lock().unwrap();
            // Work or deadline changes that raced our scan: rescan
            // rather than oversleeping them.
            if !g.is_empty() || shared.timer_dirty.swap(false, Ordering::AcqRel) {
                continue;
            }
            let _ = shared.work.wait_timeout(g, wait).unwrap();
        }
    }

    /// Private worker for one dedicated lane: waits on the lane's own
    /// condvar, closes its batches on deadline, and exits when the
    /// lane is removed and drained (or the scheduler shuts down).
    fn dedicated_loop(shared: Arc<Shared<T>>, q: Arc<QueueState<T>>) {
        loop {
            let batch = {
                let mut inner = q.inner.lock().unwrap();
                loop {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    let now = shared.now_nanos();
                    q.maybe_close_open(&mut inner, now);
                    q.flush_if_removed(&mut inner);
                    if let Some(b) = pop_earliest_deadline(&mut inner.closed) {
                        break b;
                    }
                    if q.removed.load(Ordering::SeqCst) {
                        return; // drained
                    }
                    let wait = match q.open_deadline(&inner) {
                        Some(d) if d > now => {
                            Duration::from_nanos((d - now).min(5_000_000))
                        }
                        Some(_) => continue, // expired: close it now
                        None => Duration::from_millis(5),
                    };
                    inner = q.cv.wait_timeout(inner, wait).unwrap().0;
                }
            };
            // Another private worker can take the next batch while we
            // execute this one (the lock is released here).
            q.run_batch(batch);
        }
    }

    /// Block until all queues are empty (tests/benches).
    pub fn quiesce(&self) {
        loop {
            let empty = {
                let queues = self.shared.queues.lock().unwrap();
                queues.iter().all(|q| {
                    let inner = q.inner.lock().unwrap();
                    inner.open.is_none() && inner.closed.is_empty()
                })
            };
            if empty {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

impl<T: BatchTask> Drop for SharedBatchScheduler<T> {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            // Wake the whole shared pool (shutdown is the one broadcast).
            let _g = self.shared.ready.lock().unwrap();
            self.shared.work.notify_all();
        }
        // Wake every dedicated lane's private workers.
        for q in self.shared.queues.lock().unwrap().iter() {
            q.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        for w in self.dedicated_workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[derive(Debug)]
    struct Task {
        size: usize,
        tag: usize,
    }

    impl BatchTask for Task {
        fn size(&self) -> usize {
            self.size
        }
    }

    /// `quiesce()` observes empty queues, but the last popped batch's
    /// process callback may still be running — spin until the
    /// callback-side condition holds before asserting on it.
    fn wait_until(cond: impl Fn() -> bool) {
        let deadline = Instant::now() + Duration::from_secs(5);
        while !cond() {
            assert!(Instant::now() < deadline, "condition never reached");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    fn collector() -> (
        impl Fn(Batch<Task>) + Send + Sync + 'static,
        mpsc::Receiver<Vec<(usize, usize)>>,
    ) {
        let (tx, rx) = mpsc::channel();
        (
            move |b: Batch<Task>| {
                let v: Vec<(usize, usize)> =
                    b.tasks().iter().map(|t| (t.tag, t.size)).collect();
                let _ = tx.send(v);
            },
            rx,
        )
    }

    #[test]
    fn full_batch_processes_immediately() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 4,
                batch_timeout: Duration::from_secs(100), // never by timeout
                max_enqueued_batches: 8,
                ..Default::default()
            },
            f,
        );
        for tag in 0..4 {
            q.enqueue(Task { size: 1, tag }).unwrap();
        }
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch.len(), 4);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 100,
                batch_timeout: Duration::from_millis(5),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            f,
        );
        q.enqueue(Task { size: 1, tag: 7 }).unwrap();
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch, vec![(7, 1)]);
    }

    #[test]
    fn size_units_respected() {
        // max_batch_size is in task-size units, not task count.
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(2),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            f,
        );
        q.enqueue(Task { size: 5, tag: 0 }).unwrap();
        q.enqueue(Task { size: 5, tag: 1 }).unwrap(); // doesn't fit with 0
        let b0 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let b1 = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(b0, vec![(0, 5)]);
        assert_eq!(b1, vec![(1, 5)]);
    }

    #[test]
    fn oversized_task_rejected() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, _rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions { max_batch_size: 4, ..Default::default() },
            f,
        );
        match q.enqueue(Task { size: 10, tag: 0 }) {
            Err(EnqueueError::TaskTooLarge(t)) => assert_eq!(t.tag, 0),
            other => panic!("expected TaskTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        // Block the single device thread with a slow first batch.
        let (slow_tx, slow_rx) = mpsc::channel::<()>();
        let slow_rx = Mutex::new(slow_rx);
        let blocker = sched.add_queue(
            "blocker",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::from_millis(0),
                max_enqueued_batches: 4,
                ..Default::default()
            },
            move |_b| {
                let _ = slow_rx.lock().unwrap().recv();
            },
        );
        blocker.enqueue(Task { size: 1, tag: 0 }).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // device now blocked

        let (f, _rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 1, // every task closes a batch
                batch_timeout: Duration::from_millis(0),
                max_enqueued_batches: 2,
                ..Default::default()
            },
            f,
        );
        let mut rejected = false;
        for tag in 0..10 {
            if matches!(
                q.enqueue(Task { size: 1, tag }),
                Err(EnqueueError::QueueFull(_))
            ) {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "backpressure never kicked in");
        let _ = slow_tx.send(());
        let _ = slow_tx.send(());
    }

    #[test]
    fn round_robin_across_queues() {
        // One device thread, two lanes with pre-loaded batches: the
        // processing order must interleave (each pick takes `weight`
        // batches, then the lane rotates to the back of the ready
        // list).
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let mk = |label: &'static str, order: Arc<Mutex<Vec<&'static str>>>| {
            move |_b: Batch<Task>| {
                order.lock().unwrap().push(label);
                std::thread::sleep(Duration::from_millis(1));
            }
        };
        let qa = sched.add_queue(
            "a",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
                ..Default::default()
            },
            mk("a", Arc::clone(&order)),
        );
        let qb = sched.add_queue(
            "b",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
                ..Default::default()
            },
            mk("b", Arc::clone(&order)),
        );
        for tag in 0..8 {
            qa.enqueue(Task { size: 1, tag }).unwrap();
            qb.enqueue(Task { size: 1, tag }).unwrap();
        }
        sched.quiesce();
        wait_until(|| order.lock().unwrap().len() == 16);
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 16);
        // Interleaving check: no long runs of one queue.
        let max_run = order
            .windows(4)
            .map(|w| w.iter().filter(|&&l| l == w[0]).count())
            .max()
            .unwrap();
        assert!(max_run < 4, "not interleaved: {order:?}");
        assert_eq!(qa.tasks_processed(), 8);
        assert_eq!(qb.tasks_processed(), 8);
    }

    #[test]
    fn dropped_queue_drains_then_disappears() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 10,
                batch_timeout: Duration::from_secs(100),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            f,
        );
        q.enqueue(Task { size: 1, tag: 1 }).unwrap();
        drop(q); // open batch must still flush
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch, vec![(1, 1)]);
    }

    #[test]
    fn enqueue_after_drop_fails() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, _rx) = collector();
        let q = sched.add_queue("q", QueueOptions::default(), f);
        let state = Arc::clone(&q.state);
        let shared = Arc::clone(&q.shared);
        drop(q);
        let q2 = BatchQueue { state, shared };
        assert!(matches!(
            q2.enqueue(Task { size: 1, tag: 0 }),
            Err(EnqueueError::QueueClosed(_))
        ));
    }

    #[test]
    fn many_tasks_all_processed_exactly_once() {
        let sched = SharedBatchScheduler::<Task>::new(SchedulerOptions {
            num_batch_threads: 4,
            ..Default::default()
        });
        let seen = Arc::new(Mutex::new(std::collections::HashMap::<usize, usize>::new()));
        let s2 = Arc::clone(&seen);
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 7,
                batch_timeout: Duration::from_micros(200),
                max_enqueued_batches: 1_000_000,
                ..Default::default()
            },
            move |b| {
                let mut m = s2.lock().unwrap();
                for t in b.tasks() {
                    *m.entry(t.tag).or_default() += 1;
                }
            },
        );
        const N: usize = 5000;
        for tag in 0..N {
            q.enqueue(Task { size: 1, tag }).unwrap();
        }
        sched.quiesce();
        wait_until(|| seen.lock().unwrap().len() == N);
        let m = seen.lock().unwrap();
        assert_eq!(m.len(), N);
        assert!(m.values().all(|&c| c == 1), "duplicate processing");
    }

    // ------------------------------------------------ lane isolation

    #[test]
    fn dedicated_lane_processes_without_shared_workers() {
        // Saturate the single shared worker with a never-finishing
        // batch; a dedicated lane must still process (its private
        // worker), proving full isolation from shared-pool starvation.
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let (block_tx, block_rx) = mpsc::channel::<()>();
        let block_rx = Mutex::new(block_rx);
        let blocker = sched.add_queue(
            "blocker",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
                ..Default::default()
            },
            move |_b| {
                let _ = block_rx.lock().unwrap().recv();
            },
        );
        blocker.enqueue(Task { size: 1, tag: 0 }).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // shared pool now stuck

        let (f, rx) = collector();
        let q = sched.add_queue(
            "vip",
            QueueOptions {
                max_batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 64,
                dedicated_threads: 1,
                ..Default::default()
            },
            f,
        );
        q.enqueue(Task { size: 1, tag: 42 }).unwrap();
        let batch = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("dedicated lane starved by shared-pool saturation");
        assert_eq!(batch, vec![(42, 1)]);
        let _ = block_tx.send(());
    }

    #[test]
    fn dedicated_lane_drains_on_drop() {
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "vip",
            QueueOptions {
                max_batch_size: 10,
                batch_timeout: Duration::from_secs(100),
                max_enqueued_batches: 8,
                dedicated_threads: 2,
                ..Default::default()
            },
            f,
        );
        q.enqueue(Task { size: 1, tag: 9 }).unwrap();
        drop(q); // open batch flushes through the private workers
        let batch = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(batch, vec![(9, 1)]);
    }

    #[test]
    fn weight_rotates_lane_after_its_share() {
        // Lane A (weight 2) pre-loads 6 batches; lane B (weight 1)
        // pre-loads 3. One worker, parked on a gate lane while the
        // backlogs build (so pick order is deterministic): the order
        // must show A ceding the device to B after at most `weight`
        // consecutive batches.
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate_rx = Mutex::new(gate_rx);
        let gate = sched.add_queue(
            "gate",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 4,
                ..Default::default()
            },
            move |_b| {
                let _ = gate_rx.lock().unwrap().recv();
            },
        );
        gate.enqueue(Task { size: 1, tag: 0 }).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // worker parked

        let order = Arc::new(Mutex::new(Vec::new()));
        let mk = |label: &'static str, order: Arc<Mutex<Vec<&'static str>>>| {
            move |_b: Batch<Task>| {
                order.lock().unwrap().push(label);
            }
        };
        let qa = sched.add_queue(
            "a",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
                weight: 2,
                ..Default::default()
            },
            mk("a", Arc::clone(&order)),
        );
        let qb = sched.add_queue(
            "b",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
                ..Default::default()
            },
            mk("b", Arc::clone(&order)),
        );
        for tag in 0..6 {
            qa.enqueue(Task { size: 1, tag }).unwrap();
        }
        for tag in 0..3 {
            qb.enqueue(Task { size: 1, tag }).unwrap();
        }
        let _ = gate_tx.send(()); // release the worker
        sched.quiesce();
        wait_until(|| order.lock().unwrap().len() == 9);
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 9);
        // No run of >2 consecutive "a"s: weight caps the share.
        let mut run = 0usize;
        for &l in order.iter() {
            if l == "a" {
                run += 1;
                assert!(run <= 2, "lane exceeded its weighted share: {order:?}");
            } else {
                run = 0;
            }
        }
        // And b was never starved behind a's whole backlog.
        assert!(
            order.iter().position(|&l| l == "b").unwrap() <= 2,
            "b waited behind a's whole backlog: {order:?}"
        );
    }

    #[test]
    fn edf_pick_prefers_nearest_deadline() {
        // Direct unit test of the lane-local pick: nearest deadline
        // first, deadline-free last, FIFO among the unconstrained.
        struct Timed(usize, Option<Instant>);
        impl BatchTask for Timed {
            fn size(&self) -> usize {
                1
            }
            fn deadline(&self) -> Option<Instant> {
                self.1
            }
        }
        let t0 = Instant::now();
        let mk = |tag: usize, d: Option<Duration>| {
            let mut b = Batch::new(0);
            b.push(Timed(tag, d.map(|d| t0 + d)));
            b
        };
        let mut closed: VecDeque<Batch<Timed>> = VecDeque::new();
        closed.push_back(mk(0, None));
        closed.push_back(mk(1, Some(Duration::from_millis(500))));
        closed.push_back(mk(2, Some(Duration::from_millis(10))));
        closed.push_back(mk(3, None));
        let order: Vec<usize> = std::iter::from_fn(|| {
            pop_earliest_deadline(&mut closed).map(|b| b.tasks()[0].0)
        })
        .collect();
        assert_eq!(order, vec![2, 1, 0, 3]);
        // All-FIFO lanes are untouched by the EDF path.
        let mut closed: VecDeque<Batch<Timed>> = VecDeque::new();
        for tag in 0..4 {
            closed.push_back(mk(tag, None));
        }
        let order: Vec<usize> = std::iter::from_fn(|| {
            pop_earliest_deadline(&mut closed).map(|b| b.tasks()[0].0)
        })
        .collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn urgent_batch_jumps_lane_backlog() {
        // One parked worker, one lane pre-loaded with deadline-free
        // batches plus one urgent batch: the urgent one must be
        // serviced first even though it arrived last.
        struct Timed(usize, Option<Instant>);
        impl BatchTask for Timed {
            fn size(&self) -> usize {
                1
            }
            fn deadline(&self) -> Option<Instant> {
                self.1
            }
        }
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let gate_rx = Mutex::new(gate_rx);
        let gate = sched.add_queue(
            "gate",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 4,
                ..Default::default()
            },
            move |_b: Batch<Timed>| {
                let _ = gate_rx.lock().unwrap().recv();
            },
        );
        gate.enqueue(Timed(0, None)).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // worker parked

        let order = Arc::new(Mutex::new(Vec::new()));
        let o2 = Arc::clone(&order);
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 1,
                batch_timeout: Duration::ZERO,
                max_enqueued_batches: 64,
                ..Default::default()
            },
            move |b: Batch<Timed>| {
                o2.lock().unwrap().push(b.tasks()[0].0);
            },
        );
        for tag in 1..4 {
            q.enqueue(Timed(tag, None)).unwrap();
        }
        q.enqueue(Timed(9, Some(Instant::now() + Duration::from_millis(1))))
            .unwrap();
        let _ = gate_tx.send(()); // release the worker
        sched.quiesce();
        wait_until(|| order.lock().unwrap().len() == 4);
        let order = order.lock().unwrap();
        assert_eq!(order[0], 9, "urgent batch did not jump the backlog: {order:?}");
    }

    #[test]
    fn depth_gauge_tracks_queued_rows() {
        let gauge = Arc::new(Gauge::default());
        let sched = SharedBatchScheduler::new(SchedulerOptions::default());
        let (f, rx) = collector();
        let q = sched.add_queue(
            "q",
            QueueOptions {
                max_batch_size: 100,
                batch_timeout: Duration::from_millis(100),
                max_enqueued_batches: 8,
                depth_gauge: Some(Arc::clone(&gauge)),
                ..Default::default()
            },
            f,
        );
        q.enqueue(Task { size: 3, tag: 0 }).unwrap();
        q.enqueue(Task { size: 2, tag: 1 }).unwrap();
        assert_eq!(gauge.get(), 5, "gauge should count queued rows");
        let _ = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        sched.quiesce();
        wait_until(|| gauge.get() == 0);
        assert_eq!(gauge.get(), 0, "gauge should drain with the lane");
    }
}
