//! [`BatchingSession`] — the paper's wrapper (1) around the core
//! batching library: "an implementation of TensorFlow's `Session`
//! abstraction that batches multiple `Run()` calls together,
//! concatenating their input tensors, and then forwards to the wrapped
//! `Session`'s `Run()`" (§2.2.1).
//!
//! Callers issue synchronous `run(input)` calls from many request
//! threads; the session merges concurrent inputs along the batch
//! dimension, invokes the wrapped [`BatchRunner`] (an AOT-compiled
//! executable) once, and wakes each caller with its slice.
//!
//! ## The one-copy hot path
//!
//! Merging is **fused, single-allocation assembly**: each pending
//! task's rows are written directly into one device buffer acquired
//! from a [`BufferPool`] and pre-sized to the padded ladder target, and
//! the ladder padding tail is zeroed in the same pass. That replaces
//! the naive clone → `concat` → `pad_batch` chain (three full copies of
//! the batch) with exactly one copy of each request's bytes. On the way
//! out, `truncate_batch` and `split` are O(1) metadata operations on
//! the shared output storage ([`Tensor`] is a view type), so each
//! caller receives a zero-copy window of the device's output buffer.
//!
//! Buffers recycle: the merged input buffer returns to the pool as soon
//! as the runner drops it, and request input storage is recycled after
//! its rows are assembled — steady-state serving allocates nothing on
//! this path (observable via [`BatchingSession::pool_stats`]).
//!
//! Requests larger than `max_batch_size` no longer error: `run` splits
//! them into zero-copy row-range views that batch independently and
//! reassembles the outputs (the paper's `split_input_task_func`).

use super::batch::{Batch, BatchTask};
use super::padding::pad_to_allowed;
use super::scheduler::{BatchQueue, EnqueueError, QueueOptions, SharedBatchScheduler};
use super::splitter::split_if_needed;
use crate::base::error::ErrorKind;
use crate::base::tensor::Tensor;
use crate::runtime::pjrt::OutTensor;
use crate::util::metrics::{Counter, Histogram};
use crate::util::pool::{BufferPool, PoolStats};
use anyhow::Result;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// The wrapped "device": runs one merged batch. Outputs must share the
/// input's batch dimension (f32 and i32 outputs alike — the session
/// scatters both back to callers as views).
pub trait BatchRunner: Send + Sync {
    fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>>;
}

impl<F> BatchRunner for F
where
    F: Fn(Tensor) -> Result<Vec<OutTensor>> + Send + Sync,
{
    fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
        self(input)
    }
}

/// One caller's pending `run()`.
pub struct PendingRun {
    input: Tensor,
    reply: mpsc::Sender<Result<Vec<OutTensor>>>,
    /// When the task entered the queue (queue-delay instrumentation).
    enqueued_at: Instant,
    /// Absolute deadline; expired tasks are answered
    /// `DEADLINE_EXCEEDED` and dropped *before* the device call.
    deadline: Option<Instant>,
}

impl BatchTask for PendingRun {
    fn size(&self) -> usize {
        self.input.batch()
    }

    fn deadline(&self) -> Option<Instant> {
        self.deadline
    }
}

/// Options for a batching session.
#[derive(Clone)]
pub struct SessionOptions {
    pub queue: QueueOptions,
    /// Ladder of compiled batch sizes; merged batches pad up to the
    /// nearest. Empty = no padding (dynamic-shape device).
    pub allowed_batch_sizes: Vec<usize>,
    /// Optional histogram recording each task's enqueue→execute delay
    /// in nanoseconds (the latency cost of waiting for batch-mates).
    pub queue_delay_ns: Option<Arc<Histogram>>,
    /// Optional *windowed* sibling of `queue_delay_ns`: same samples,
    /// but over a rotating window, so scrapers (fleet autoscaling) see
    /// recent queue pressure instead of the cumulative distribution.
    pub queue_delay_window: Option<Arc<crate::util::metrics::WindowedHistogram>>,
    /// Optional histogram recording merged task rows per device batch
    /// (pre-padding — the actual cross-request merge factor).
    pub merged_batch_rows: Option<Arc<Histogram>>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            queue: QueueOptions::default(),
            allowed_batch_sizes: vec![1, 4, 16],
            queue_delay_ns: None,
            queue_delay_window: None,
            merged_batch_rows: None,
        }
    }
}

/// Hot-path instrumentation: exactly one buffer acquisition per merged
/// batch, and the bytes physically copied during assembly (the only
/// copy on the input path).
#[derive(Default)]
struct AssemblyCounters {
    buffer_acquisitions: Counter,
    bytes_copied: Counter,
}

pub struct BatchingSession {
    queue: BatchQueue<PendingRun>,
    max_batch_size: usize,
    pool: Arc<BufferPool>,
    counters: Arc<AssemblyCounters>,
}

impl BatchingSession {
    /// Attach a new session queue to `scheduler`, executing on `runner`,
    /// with batch buffers recycled through the process-global pool.
    pub fn new(
        scheduler: &SharedBatchScheduler<PendingRun>,
        name: &str,
        options: SessionOptions,
        runner: Arc<dyn BatchRunner>,
    ) -> Self {
        Self::with_pool(scheduler, name, options, runner, BufferPool::global())
    }

    /// Like [`BatchingSession::new`] with an explicit buffer pool
    /// (tests and multi-tenant servers that want isolated accounting).
    pub fn with_pool(
        scheduler: &SharedBatchScheduler<PendingRun>,
        name: &str,
        options: SessionOptions,
        runner: Arc<dyn BatchRunner>,
        pool: Arc<BufferPool>,
    ) -> Self {
        let allowed = options.allowed_batch_sizes.clone();
        let counters = Arc::new(AssemblyCounters::default());
        let max_batch_size = options.queue.max_batch_size;
        let delay_hist = options.queue_delay_ns.clone();
        let delay_window = options.queue_delay_window.clone();
        let rows_hist = options.merged_batch_rows.clone();
        let process_pool = Arc::clone(&pool);
        let process_counters = Arc::clone(&counters);
        let queue = scheduler.add_queue(name, options.queue, move |batch| {
            Self::process(
                &allowed,
                runner.as_ref(),
                &process_pool,
                &process_counters,
                delay_hist.as_deref(),
                delay_window.as_deref(),
                rows_hist.as_deref(),
                batch,
            );
        });
        BatchingSession { queue, max_batch_size, pool, counters }
    }

    /// Fused assembly + dispatch + zero-copy scatter for one merged
    /// batch.
    fn process(
        allowed: &[usize],
        runner: &dyn BatchRunner,
        pool: &BufferPool,
        counters: &AssemblyCounters,
        delay_hist: Option<&Histogram>,
        delay_window: Option<&crate::util::metrics::WindowedHistogram>,
        rows_hist: Option<&Histogram>,
        batch: Batch<PendingRun>,
    ) {
        let all = batch.into_tasks();
        if delay_hist.is_some() || delay_window.is_some() {
            for t in &all {
                let waited = t.enqueued_at.elapsed();
                if let Some(h) = delay_hist {
                    h.record_duration(waited);
                }
                if let Some(w) = delay_window {
                    w.record_duration(waited);
                }
            }
        }
        // Deadline check at the last possible moment before device
        // work: tasks that expired while queued are answered
        // DEADLINE_EXCEEDED and never executed — the whole point of a
        // deadline is not to burn a device slot on an answer nobody is
        // waiting for. Their input storage recycles like any other.
        let now = Instant::now();
        let (expired, tasks): (Vec<PendingRun>, Vec<PendingRun>) =
            all.into_iter().partition(|t| t.deadline.is_some_and(|d| now >= d));
        for t in expired {
            t.input.recycle_into(pool);
            let _ = t.reply.send(Err(ErrorKind::DeadlineExceeded
                .err("deadline expired while queued; dropped before execution")));
        }
        if tasks.is_empty() {
            return;
        }
        let (inputs, replies): (Vec<Tensor>, Vec<mpsc::Sender<Result<Vec<OutTensor>>>>) =
            tasks.into_iter().map(|t| (t.input, t.reply)).unzip();
        let sizes: Vec<usize> = inputs.iter().map(Tensor::batch).collect();
        let merged_rows: usize = sizes.iter().sum();
        if let Some(h) = rows_hist {
            h.record(merged_rows as u64);
        }

        let result: Result<Vec<Vec<OutTensor>>> = (|| {
            // Same compatibility rules as Tensor::concat, one helper.
            let (_, trailing) = Tensor::concat_shape(&inputs)?;
            // Pad up to the compiled batch-size ladder.
            let target = if allowed.is_empty() {
                merged_rows
            } else {
                pad_to_allowed(merged_rows, allowed).ok_or_else(|| {
                    ErrorKind::InvalidArgument
                        .err(format!("batch {merged_rows} exceeds ladder {allowed:?}"))
                })?
            };

            // The single acquisition + single copy: every task's rows go
            // straight into the pooled device buffer, padding zeroed in
            // the same pass.
            let mut shape = vec![target];
            shape.extend_from_slice(&trailing);
            counters.buffer_acquisitions.inc();
            let merged = Tensor::build_with(shape, pool, |buf| {
                let mut off = 0usize;
                for t in &inputs {
                    let d = t.data();
                    buf[off..off + d.len()].copy_from_slice(d);
                    off += d.len();
                }
                buf[off..].fill(0.0);
            });
            counters
                .bytes_copied
                .add((merged.row_elems() * merged_rows * std::mem::size_of::<f32>()) as u64);

            // Request storage has been assembled; recycle it for the
            // RPC decode path (no-op for buffers still shared).
            for input in inputs {
                input.recycle_into(pool);
            }

            // Offer the device buffer back after the run. Runners drop
            // their input tensor on return, making the release a
            // recycle; a runner that retains a view keeps the buffer
            // alive and the pool just declines it.
            let merged_storage = Arc::clone(merged.storage());
            let outputs = runner.run_batch(merged)?;
            pool.release(merged_storage);

            // Un-pad + scatter: all views of the shared output storage.
            let mut per_task: Vec<Vec<OutTensor>> = vec![Vec::new(); sizes.len()];
            for out in outputs {
                let trimmed = out.truncate_batch(merged_rows)?;
                for (i, piece) in trimmed.split(&sizes)?.into_iter().enumerate() {
                    per_task[i].push(piece);
                }
            }
            Ok(per_task)
        })();

        match result {
            Ok(per_task) => {
                for (reply, outs) in replies.into_iter().zip(per_task) {
                    let _ = reply.send(Ok(outs));
                }
            }
            Err(e) => {
                // Device failure propagates to every caller in the
                // batch, preserving the error's kind (so e.g. a
                // FailedPrecondition from an unload-gated runner stays
                // retryable on the wire).
                let kind = ErrorKind::of(&e);
                let message = format!("batch run failed: {e}");
                for reply in replies {
                    let _ = reply.send(Err(kind.err(message.clone())));
                }
            }
        }
    }

    /// Synchronous batched run: blocks until this input's slice of a
    /// merged batch has been computed. Inputs larger than
    /// `max_batch_size` are transparently split into zero-copy row
    /// chunks that batch independently.
    pub fn run(&self, input: Tensor) -> Result<Vec<OutTensor>> {
        self.run_with_deadline(input, None)
    }

    /// [`BatchingSession::run`] with an absolute deadline: refused
    /// immediately if already expired, and dropped (never executed) if
    /// it expires while waiting in the queue. The deadline also makes
    /// this task's batch eligible for the scheduler's EDF pick.
    pub fn run_with_deadline(
        &self,
        input: Tensor,
        deadline: Option<Instant>,
    ) -> Result<Vec<OutTensor>> {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(ErrorKind::DeadlineExceeded
                .err("deadline expired before enqueue"));
        }
        if input.rank() > 0 && input.batch() > self.max_batch_size {
            return self.run_split(input, deadline);
        }
        let (tx, rx) = mpsc::channel();
        self.enqueue(PendingRun { input, reply: tx, enqueued_at: Instant::now(), deadline })?;
        rx.recv()
            .map_err(|_| ErrorKind::Internal.err("session dropped reply"))?
    }

    fn enqueue(&self, task: PendingRun) -> Result<()> {
        self.queue.enqueue(task).map_err(|e| match e {
            // Load shedding is transient by construction: Unavailable
            // on the wire, so well-behaved clients back off and retry.
            EnqueueError::QueueFull(_) => {
                ErrorKind::Unavailable.err("overloaded: queue full")
            }
            EnqueueError::TaskTooLarge(t) => ErrorKind::InvalidArgument.err(format!(
                "request batch {} exceeds max_batch_size {}",
                t.input.batch(),
                self.max_batch_size
            )),
            EnqueueError::QueueClosed(_) => {
                ErrorKind::FailedPrecondition.err("session closed")
            }
        })
    }

    /// Oversized request: **parallel chunk dispatch**. Every zero-copy
    /// row-range view (the splitter's [`SplittableTask`] impl for
    /// tensors) is enqueued up front — full-ladder chunks each close a
    /// batch immediately — and only then does the caller start the
    /// rendezvous, so distinct device workers service the chunks
    /// concurrently and the request's latency approaches
    /// max-chunk-time rather than sum-of-chunks. (The scheduler hands
    /// a lane's entry back to the ready list *before* executing a
    /// batch, which is what lets several workers drain one lane's
    /// chunk backlog in parallel.) Outputs reassemble in order.
    ///
    /// If a later chunk is refused (load shed / teardown), the whole
    /// request errors; already-dispatched chunks still execute but
    /// their replies land in dropped receivers — harmless, and their
    /// buffers recycle through the pool as usual.
    ///
    /// [`SplittableTask`]: super::splitter::SplittableTask
    fn run_split(&self, input: Tensor, deadline: Option<Instant>) -> Result<Vec<OutTensor>> {
        let parts = split_if_needed(input, self.max_batch_size);
        // Dispatch phase: all chunks in flight before any wait.
        let receivers: Vec<mpsc::Receiver<Result<Vec<OutTensor>>>> = parts
            .into_iter()
            .map(|part| {
                let (tx, rx) = mpsc::channel();
                self.enqueue(PendingRun {
                    input: part,
                    reply: tx,
                    enqueued_at: Instant::now(),
                    deadline,
                })?;
                Ok(rx)
            })
            .collect::<Result<_>>()?;
        // Rendezvous phase: collect in order (completion order does
        // not matter; the slowest chunk bounds latency).
        let mut per_part: Vec<Vec<OutTensor>> = Vec::with_capacity(receivers.len());
        for rx in receivers {
            per_part.push(
                rx.recv()
                    .map_err(|_| ErrorKind::Internal.err("session dropped reply"))??,
            );
        }
        let n_outputs = per_part.first().map_or(0, Vec::len);
        (0..n_outputs)
            .map(|k| {
                let pieces: Vec<OutTensor> =
                    per_part.iter().map(|outs| outs[k].clone()).collect();
                OutTensor::concat(&pieces)
            })
            .collect()
    }

    /// Close the session's queue immediately (idempotent): the open
    /// batch flushes to the runner now, and later `run` calls fail
    /// with a retryable "session closed" error. Dropping the session
    /// closes implicitly; the serving layer calls this explicitly on
    /// unload so draining never waits out a batch timeout.
    pub fn close(&self) {
        self.queue.close();
    }

    pub fn batches_processed(&self) -> u64 {
        self.queue.batches_processed()
    }

    pub fn tasks_processed(&self) -> u64 {
        self.queue.tasks_processed()
    }

    /// Tasks currently waiting in the queue (monitoring/tests).
    pub fn pending_tasks(&self) -> usize {
        self.queue.pending_tasks()
    }

    /// Device-buffer acquisitions performed by assembly (exactly one
    /// per merged batch — the single-allocation invariant).
    pub fn buffer_acquisitions(&self) -> u64 {
        self.counters.buffer_acquisitions.get()
    }

    /// Bytes physically copied assembling inputs (the one copy per
    /// request on the input path; output scatter copies nothing).
    pub fn bytes_copied(&self) -> u64 {
        self.counters.bytes_copied.get()
    }

    /// Hit/miss/recycle counters of this session's buffer pool.
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::scheduler::SchedulerOptions;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    /// Device doubling each element; also records batch sizes it saw.
    struct DoublingRunner {
        seen_batches: Arc<std::sync::Mutex<Vec<usize>>>,
    }

    impl BatchRunner for DoublingRunner {
        fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
            self.seen_batches.lock().unwrap().push(input.batch());
            let doubled: Vec<f32> = input.data().iter().map(|x| x * 2.0).collect();
            Ok(vec![OutTensor::F32(Tensor::new(input.shape().to_vec(), doubled)?)])
        }
    }

    fn setup(
        opts: SessionOptions,
    ) -> (
        SharedBatchScheduler<PendingRun>,
        BatchingSession,
        Arc<std::sync::Mutex<Vec<usize>>>,
    ) {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 2,
            ..Default::default()
        });
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let runner = Arc::new(DoublingRunner { seen_batches: Arc::clone(&seen) });
        let session = BatchingSession::new(&sched, "s", opts, runner);
        (sched, session, seen)
    }

    #[test]
    fn single_run_roundtrip() {
        let (_sched, session, _seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 16,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![1, 4, 16],
            ..Default::default()
        });
        let out = session
            .run(Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap())
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].as_f32().unwrap().data(), &[2.0, 4.0]);
        assert_eq!(out[0].as_f32().unwrap().shape(), &[1, 2]);
    }

    #[test]
    fn concurrent_runs_share_one_device_batch() {
        let (_sched, session, seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(20),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![1, 4, 8],
            ..Default::default()
        });
        let session = Arc::new(session);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let s = Arc::clone(&session);
                std::thread::spawn(move || {
                    s.run(Tensor::matrix(vec![vec![i as f32]]).unwrap()).unwrap()
                })
            })
            .collect();
        let outs: Vec<Vec<OutTensor>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Each caller got its own doubled row back.
        let mut got: Vec<f32> = outs
            .iter()
            .map(|o| o[0].as_f32().unwrap().data()[0])
            .collect();
        got.sort_by(f32::total_cmp);
        assert_eq!(got, (0..8).map(|i| 2.0 * i as f32).collect::<Vec<_>>());
        // Fewer device invocations than callers = real merging.
        let batches = seen.lock().unwrap();
        assert!(
            batches.len() < 8,
            "no batching happened: {batches:?}"
        );
    }

    #[test]
    fn padding_to_allowed_sizes() {
        let (_sched, session, seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 16,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![4, 16],
            ..Default::default()
        });
        // A 2-row request must execute as a 4-row padded batch.
        let out = session
            .run(Tensor::matrix(vec![vec![1.0], vec![3.0]]).unwrap())
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap().shape(), &[2, 1]);
        assert_eq!(out[0].as_f32().unwrap().data(), &[2.0, 6.0]);
        assert_eq!(seen.lock().unwrap().as_slice(), &[4]);
    }

    #[test]
    fn multi_row_requests_interleave_correctly() {
        let (_sched, session, _seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(10),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![8],
            ..Default::default()
        });
        let session = Arc::new(session);
        let a = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || {
                s.run(Tensor::matrix(vec![vec![1.0], vec![2.0], vec![3.0]]).unwrap())
                    .unwrap()
            })
        };
        let b = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || {
                s.run(Tensor::matrix(vec![vec![10.0], vec![20.0]]).unwrap()).unwrap()
            })
        };
        let ra = a.join().unwrap();
        let rb = b.join().unwrap();
        assert_eq!(ra[0].as_f32().unwrap().data(), &[2.0, 4.0, 6.0]);
        assert_eq!(rb[0].as_f32().unwrap().data(), &[20.0, 40.0]);
    }

    #[test]
    fn device_error_propagates_to_all_callers() {
        let sched = SharedBatchScheduler::<PendingRun>::new(SchedulerOptions::default());
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let runner = Arc::new(move |_input: Tensor| -> Result<Vec<OutTensor>> {
            c.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("device on fire")
        });
        let session = BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 4,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 8,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![4],
                ..Default::default()
            },
            runner,
        );
        let err = session
            .run(Tensor::matrix(vec![vec![1.0]]).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("device on fire"));
    }

    #[test]
    fn oversized_request_splits_transparently() {
        let (_sched, session, seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![4],
            ..Default::default()
        });
        // 10 rows > max_batch_size 4: split into 4+4+2, reassembled in
        // order with every row doubled.
        let rows: Vec<Vec<f32>> = (0..10).map(|i| vec![i as f32]).collect();
        let out = session.run(Tensor::matrix(rows).unwrap()).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].as_f32().unwrap().shape(), &[10, 1]);
        let want: Vec<f32> = (0..10).map(|i| 2.0 * i as f32).collect();
        assert_eq!(out[0].as_f32().unwrap().data(), &want[..]);
        // Every device batch stayed on the ladder.
        assert!(seen.lock().unwrap().iter().all(|&b| b == 4));
    }

    // ------------------------------------ zero-copy / pool invariants

    /// Runner that remembers the exact output tensor it returned, so
    /// the test can check callers received views of the same storage.
    struct EchoRunner {
        returned: Arc<std::sync::Mutex<Vec<Tensor>>>,
    }

    impl BatchRunner for EchoRunner {
        fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
            let out = Tensor::new(input.shape().to_vec(), input.data().to_vec())?;
            self.returned.lock().unwrap().push(out.clone());
            Ok(vec![OutTensor::F32(out)])
        }
    }

    #[test]
    fn outputs_are_views_of_the_device_buffer() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let returned = Arc::new(std::sync::Mutex::new(Vec::new()));
        let runner = Arc::new(EchoRunner { returned: Arc::clone(&returned) });
        let session = BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 8,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 8,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![8],
                ..Default::default()
            },
            runner,
        );
        let out = session
            .run(Tensor::matrix(vec![vec![5.0, 6.0]]).unwrap())
            .unwrap();
        let device_outputs = returned.lock().unwrap();
        assert_eq!(device_outputs.len(), 1);
        assert!(
            out[0].as_f32().unwrap().shares_storage(&device_outputs[0]),
            "caller output was copied, not a view of the device buffer"
        );
        assert_eq!(out[0].as_f32().unwrap().data(), &[5.0, 6.0]);
    }

    #[test]
    fn one_acquisition_per_batch_and_buffers_recycle() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let runner = Arc::new(DoublingRunner { seen_batches: Arc::clone(&seen) });
        let pool = Arc::new(BufferPool::new(8, 1 << 20));
        let session = BatchingSession::with_pool(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 16,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 8,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![4, 16],
                ..Default::default()
            },
            runner,
            Arc::clone(&pool),
        );
        // First batch: the device buffer is a pool miss…
        session.run(Tensor::matrix(vec![vec![1.0], vec![2.0]]).unwrap()).unwrap();
        assert_eq!(session.buffer_acquisitions(), 1);
        assert_eq!(session.pool_stats().misses, 1);
        // …and recycles after the run, so the same-ladder second batch
        // is a hit: still exactly one acquisition per batch, zero new
        // allocations.
        session.run(Tensor::matrix(vec![vec![3.0], vec![4.0]]).unwrap()).unwrap();
        assert_eq!(session.buffer_acquisitions(), 2);
        let stats = session.pool_stats();
        assert_eq!(stats.misses, 1, "second batch re-allocated: {stats:?}");
        assert_eq!(stats.hits, 1);
        // Bytes copied = one copy of each request's payload (2 rows × 1
        // col × 4 bytes, twice).
        assert_eq!(session.bytes_copied(), 16);
    }

    /// Classifier-shaped device: f32 [rows, 1] scores plus an i32
    /// [rows] class per row — proves the mixed-dtype scatter path the
    /// serving registry relies on.
    struct ClassifierRunner;

    impl BatchRunner for ClassifierRunner {
        fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
            let rows = input.batch();
            let scores: Vec<f32> = (0..rows).map(|i| input.row(i)[0] * 10.0).collect();
            let classes: Vec<i32> = (0..rows).map(|i| input.row(i)[0] as i32).collect();
            Ok(vec![
                OutTensor::F32(Tensor::new(vec![rows, 1], scores)?),
                OutTensor::I32(crate::base::tensor::TensorI32::new(vec![rows], classes)?),
            ])
        }
    }

    #[test]
    fn mixed_dtype_outputs_scatter_per_caller() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 2,
            ..Default::default()
        });
        let session = Arc::new(BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 8,
                    batch_timeout: Duration::from_millis(20),
                    max_enqueued_batches: 8,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![8],
                ..Default::default()
            },
            Arc::new(ClassifierRunner),
        ));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let s = Arc::clone(&session);
                std::thread::spawn(move || {
                    s.run(Tensor::matrix(vec![vec![i as f32]]).unwrap()).unwrap()
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let outs = h.join().unwrap();
            assert_eq!(outs[0].as_f32().unwrap().data(), &[i as f32 * 10.0]);
            assert_eq!(outs[1].as_i32().unwrap().data(), &[i as i32]);
        }
    }

    #[test]
    fn queue_delay_and_merge_histograms_record() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let runner = Arc::new(DoublingRunner { seen_batches: Arc::clone(&seen) });
        let delay = Arc::new(Histogram::new());
        let merged = Arc::new(Histogram::new());
        let session = BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 16,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 8,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![16],
                queue_delay_ns: Some(Arc::clone(&delay)),
                merged_batch_rows: Some(Arc::clone(&merged)),
            },
            runner,
        );
        session.run(Tensor::matrix(vec![vec![1.0], vec![2.0]]).unwrap()).unwrap();
        // One task delayed at least the batch timeout; one merged batch
        // of exactly the task's 2 rows (padding is not counted).
        assert_eq!(delay.count(), 1);
        assert!(delay.max() > 0);
        assert_eq!(merged.count(), 1);
        assert_eq!(merged.max(), 2);
    }

    #[test]
    fn mismatched_shapes_in_one_batch_error() {
        let (_sched, session, _seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(20),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![8],
            ..Default::default()
        });
        let session = Arc::new(session);
        let a = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || s.run(Tensor::zeros(vec![1, 2])))
        };
        let b = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || s.run(Tensor::zeros(vec![1, 3])))
        };
        let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
        // Either both landed in one batch (both fail on shape mismatch)
        // or timing separated them (both succeed); a mix of one success
        // and one failure is impossible.
        assert_eq!(ra.is_ok(), rb.is_ok(), "partial batch failure");
    }

    #[test]
    fn expired_deadline_refused_before_enqueue() {
        let (_sched, session, seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 4,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 8,
                ..Default::default()
            },
            allowed_batch_sizes: vec![4],
            ..Default::default()
        });
        let past = Instant::now() - Duration::from_millis(5);
        let e = session
            .run_with_deadline(Tensor::matrix(vec![vec![1.0]]).unwrap(), Some(past))
            .unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::DeadlineExceeded);
        std::thread::sleep(Duration::from_millis(10));
        assert!(seen.lock().unwrap().is_empty(), "expired request reached the device");
        // A live deadline still executes normally.
        let out = session
            .run_with_deadline(
                Tensor::matrix(vec![vec![3.0]]).unwrap(),
                Some(Instant::now() + Duration::from_secs(10)),
            )
            .unwrap();
        assert_eq!(out[0].as_f32().unwrap().data(), &[6.0]);
    }

    /// A task whose deadline lapses *while queued* behind a slow batch
    /// is answered DEADLINE_EXCEEDED and its batch never executes —
    /// the drop-before-execution invariant, end to end through the
    /// scheduler.
    #[test]
    fn deadline_expiring_in_queue_drops_before_execution() {
        struct SlowCounting {
            executed: Arc<AtomicUsize>,
        }
        impl BatchRunner for SlowCounting {
            fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
                self.executed.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(60));
                Ok(vec![OutTensor::F32(Tensor::new(
                    input.shape().to_vec(),
                    input.data().to_vec(),
                )?)])
            }
        }
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1, // one worker: the slow batch blocks the lane
            ..Default::default()
        });
        let executed = Arc::new(AtomicUsize::new(0));
        let session = Arc::new(BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 1, // every task is its own batch
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 8,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![1],
                ..Default::default()
            },
            Arc::new(SlowCounting { executed: Arc::clone(&executed) }),
        ));
        // Occupy the only worker with a deadline-free slow batch.
        let blocker = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || s.run(Tensor::matrix(vec![vec![1.0]]).unwrap()))
        };
        while executed.load(Ordering::SeqCst) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        // This task's 10ms budget lapses long before the 60ms blocker
        // frees the worker: it must be dropped, not executed.
        let e = session
            .run_with_deadline(
                Tensor::matrix(vec![vec![2.0]]).unwrap(),
                Some(Instant::now() + Duration::from_millis(10)),
            )
            .unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::DeadlineExceeded);
        assert!(e.to_string().contains("dropped before execution"), "{e}");
        blocker.join().unwrap().unwrap();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(
            executed.load(Ordering::SeqCst),
            1,
            "the expired task's batch reached the device"
        );
    }

    #[test]
    fn queue_full_sheds_with_unavailable() {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 1,
            ..Default::default()
        });
        let session = Arc::new(BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 1,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 1,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![1],
                ..Default::default()
            },
            Arc::new(|input: Tensor| -> Result<Vec<OutTensor>> {
                std::thread::sleep(Duration::from_millis(40));
                Ok(vec![OutTensor::F32(input)])
            }),
        ));
        // Saturate: each enqueue closes its own 1-row batch and the
        // 40ms device drains far slower than this loop fills, so the
        // 1-batch cap must overflow. Dropped receivers are harmless.
        let mut shed = None;
        for i in 0..16 {
            let (tx, _rx) = mpsc::channel();
            let task = PendingRun {
                input: Tensor::matrix(vec![vec![i as f32]]).unwrap(),
                reply: tx,
                enqueued_at: Instant::now(),
                deadline: None,
            };
            if let Err(e) = session.enqueue(task) {
                shed = Some(e);
                break;
            }
        }
        let e = shed.expect("queue never filled");
        assert_eq!(ErrorKind::of(&e), ErrorKind::Unavailable);
        assert!(e.to_string().contains("overloaded"), "{e}");
    }

    /// A slow device + several workers: a split request's chunks must
    /// execute concurrently (latency ≈ max-chunk), not serially
    /// (sum-of-chunks) — the parallel-chunk-dispatch guarantee.
    #[test]
    fn split_chunks_are_serviced_in_parallel() {
        struct SlowDoubling;
        impl BatchRunner for SlowDoubling {
            fn run_batch(&self, input: Tensor) -> Result<Vec<OutTensor>> {
                std::thread::sleep(Duration::from_millis(30));
                let doubled: Vec<f32> = input.data().iter().map(|x| x * 2.0).collect();
                Ok(vec![OutTensor::F32(Tensor::new(input.shape().to_vec(), doubled)?)])
            }
        }
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 4,
            ..Default::default()
        });
        let session = BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 4,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 64,
                    ..Default::default()
                },
                allowed_batch_sizes: vec![4],
                ..Default::default()
            },
            Arc::new(SlowDoubling),
        );
        // 16 rows > max_batch_size 4 → four full chunks, each closing
        // a device batch the moment it is enqueued.
        let rows: Vec<Vec<f32>> = (0..16).map(|i| vec![i as f32]).collect();
        let t0 = std::time::Instant::now();
        let out = session.run(Tensor::matrix(rows).unwrap()).unwrap();
        let elapsed = t0.elapsed();
        let want: Vec<f32> = (0..16).map(|i| 2.0 * i as f32).collect();
        assert_eq!(out[0].as_f32().unwrap().shape(), &[16, 1]);
        assert_eq!(out[0].as_f32().unwrap().data(), &want[..]);
        // 4 chunks × 30ms of device time: concurrent service lands
        // near 30ms; the serial path would take 120ms. The generous
        // bound keeps CI noise out while still catching serialization.
        assert!(
            elapsed < Duration::from_millis(90),
            "split chunks served serially: {elapsed:?}"
        );
    }
}
