//! [`BatchingSession`] — the paper's wrapper (1) around the core
//! batching library: "an implementation of TensorFlow's `Session`
//! abstraction that batches multiple `Run()` calls together,
//! concatenating their input tensors, and then forwards to the wrapped
//! `Session`'s `Run()`" (§2.2.1).
//!
//! Callers issue synchronous `run(input)` calls from many request
//! threads; the session concatenates concurrent inputs along the batch
//! dimension, pads to an allowed batch size, invokes the wrapped
//! [`BatchRunner`] (an AOT-compiled executable) once, splits the merged
//! outputs, and wakes each caller with its slice.

use super::batch::{Batch, BatchTask};
use super::padding::pad_to_allowed;
use super::scheduler::{BatchQueue, EnqueueError, QueueOptions, SharedBatchScheduler};
use crate::base::tensor::Tensor;
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::Arc;

/// The wrapped "device": runs one merged batch. Outputs must share the
/// input's batch dimension.
pub trait BatchRunner: Send + Sync {
    fn run_batch(&self, input: Tensor) -> Result<Vec<Tensor>>;
}

impl<F> BatchRunner for F
where
    F: Fn(Tensor) -> Result<Vec<Tensor>> + Send + Sync,
{
    fn run_batch(&self, input: Tensor) -> Result<Vec<Tensor>> {
        self(input)
    }
}

/// One caller's pending `run()`.
pub struct PendingRun {
    input: Tensor,
    reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

impl BatchTask for PendingRun {
    fn size(&self) -> usize {
        self.input.batch()
    }
}

/// Options for a batching session.
#[derive(Debug, Clone)]
pub struct SessionOptions {
    pub queue: QueueOptions,
    /// Ladder of compiled batch sizes; merged batches pad up to the
    /// nearest. Empty = no padding (dynamic-shape device).
    pub allowed_batch_sizes: Vec<usize>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            queue: QueueOptions::default(),
            allowed_batch_sizes: vec![1, 4, 16],
        }
    }
}

pub struct BatchingSession {
    queue: BatchQueue<PendingRun>,
}

impl BatchingSession {
    /// Attach a new session queue to `scheduler`, executing on `runner`.
    pub fn new(
        scheduler: &SharedBatchScheduler<PendingRun>,
        name: &str,
        options: SessionOptions,
        runner: Arc<dyn BatchRunner>,
    ) -> Self {
        let allowed = options.allowed_batch_sizes.clone();
        let queue = scheduler.add_queue(name, options.queue, move |batch| {
            Self::process(&allowed, runner.as_ref(), batch);
        });
        BatchingSession { queue }
    }

    fn process(allowed: &[usize], runner: &dyn BatchRunner, batch: Batch<PendingRun>) {
        let tasks = batch.into_tasks();
        let sizes: Vec<usize> = tasks.iter().map(|t| t.input.batch()).collect();
        let merged_rows: usize = sizes.iter().sum();

        let result: Result<Vec<Vec<Tensor>>> = (|| {
            let inputs: Vec<Tensor> = tasks.iter().map(|t| t.input.clone()).collect();
            let mut merged = Tensor::concat(&inputs)?;
            // Pad up to the compiled batch-size ladder.
            if !allowed.is_empty() {
                let target = pad_to_allowed(merged_rows, allowed)
                    .ok_or_else(|| anyhow!("batch {merged_rows} exceeds ladder {allowed:?}"))?;
                merged = merged.pad_batch(target)?;
            }
            let outputs = runner.run_batch(merged)?;
            // Un-pad, then split each output tensor back per caller.
            let mut per_task: Vec<Vec<Tensor>> = vec![Vec::new(); tasks.len()];
            for out in outputs {
                let trimmed = out.truncate_batch(merged_rows)?;
                for (i, piece) in trimmed.split(&sizes)?.into_iter().enumerate() {
                    per_task[i].push(piece);
                }
            }
            Ok(per_task)
        })();

        match result {
            Ok(per_task) => {
                for (task, outs) in tasks.into_iter().zip(per_task) {
                    let _ = task.reply.send(Ok(outs));
                }
            }
            Err(e) => {
                // Device failure propagates to every caller in the batch.
                for task in tasks {
                    let _ = task.reply.send(Err(anyhow!("batch run failed: {e}")));
                }
            }
        }
    }

    /// Synchronous batched run: blocks until this input's slice of a
    /// merged batch has been computed.
    pub fn run(&self, input: Tensor) -> Result<Vec<Tensor>> {
        let (tx, rx) = mpsc::channel();
        self.queue
            .enqueue(PendingRun { input, reply: tx })
            .map_err(|e| match e {
                EnqueueError::QueueFull(_) => anyhow!("overloaded: queue full"),
                EnqueueError::TaskTooLarge(t) => anyhow!(
                    "request batch {} exceeds max_batch_size (use the splitter)",
                    t.input.batch()
                ),
                EnqueueError::QueueClosed(_) => anyhow!("session closed"),
            })?;
        rx.recv().map_err(|_| anyhow!("session dropped reply"))?
    }

    pub fn batches_processed(&self) -> u64 {
        self.queue.batches_processed()
    }

    pub fn tasks_processed(&self) -> u64 {
        self.queue.tasks_processed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::scheduler::SchedulerOptions;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    /// Device doubling each element; also records batch sizes it saw.
    struct DoublingRunner {
        seen_batches: Arc<std::sync::Mutex<Vec<usize>>>,
    }

    impl BatchRunner for DoublingRunner {
        fn run_batch(&self, input: Tensor) -> Result<Vec<Tensor>> {
            self.seen_batches.lock().unwrap().push(input.batch());
            let doubled: Vec<f32> = input.data().iter().map(|x| x * 2.0).collect();
            Ok(vec![Tensor::new(input.shape().to_vec(), doubled)?])
        }
    }

    fn setup(
        opts: SessionOptions,
    ) -> (
        SharedBatchScheduler<PendingRun>,
        BatchingSession,
        Arc<std::sync::Mutex<Vec<usize>>>,
    ) {
        let sched = SharedBatchScheduler::new(SchedulerOptions {
            num_batch_threads: 2,
            ..Default::default()
        });
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let runner = Arc::new(DoublingRunner { seen_batches: Arc::clone(&seen) });
        let session = BatchingSession::new(&sched, "s", opts, runner);
        (sched, session, seen)
    }

    #[test]
    fn single_run_roundtrip() {
        let (_sched, session, _seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 16,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 8,
            },
            allowed_batch_sizes: vec![1, 4, 16],
        });
        let out = session
            .run(Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap())
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data(), &[2.0, 4.0]);
        assert_eq!(out[0].shape(), &[1, 2]);
    }

    #[test]
    fn concurrent_runs_share_one_device_batch() {
        let (_sched, session, seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(20),
                max_enqueued_batches: 8,
            },
            allowed_batch_sizes: vec![1, 4, 8],
        });
        let session = Arc::new(session);
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let s = Arc::clone(&session);
                std::thread::spawn(move || {
                    s.run(Tensor::matrix(vec![vec![i as f32]]).unwrap()).unwrap()
                })
            })
            .collect();
        let outs: Vec<Vec<Tensor>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Each caller got its own doubled row back.
        let mut got: Vec<f32> = outs.iter().map(|o| o[0].data()[0]).collect();
        got.sort_by(f32::total_cmp);
        assert_eq!(got, (0..8).map(|i| 2.0 * i as f32).collect::<Vec<_>>());
        // Fewer device invocations than callers = real merging.
        let batches = seen.lock().unwrap();
        assert!(
            batches.len() < 8,
            "no batching happened: {batches:?}"
        );
    }

    #[test]
    fn padding_to_allowed_sizes() {
        let (_sched, session, seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 16,
                batch_timeout: Duration::from_millis(1),
                max_enqueued_batches: 8,
            },
            allowed_batch_sizes: vec![4, 16],
        });
        // A 2-row request must execute as a 4-row padded batch.
        let out = session
            .run(Tensor::matrix(vec![vec![1.0], vec![3.0]]).unwrap())
            .unwrap();
        assert_eq!(out[0].shape(), &[2, 1]);
        assert_eq!(out[0].data(), &[2.0, 6.0]);
        assert_eq!(seen.lock().unwrap().as_slice(), &[4]);
    }

    #[test]
    fn multi_row_requests_interleave_correctly() {
        let (_sched, session, _seen) = setup(SessionOptions {
            queue: QueueOptions {
                max_batch_size: 8,
                batch_timeout: Duration::from_millis(10),
                max_enqueued_batches: 8,
            },
            allowed_batch_sizes: vec![8],
        });
        let session = Arc::new(session);
        let a = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || {
                s.run(Tensor::matrix(vec![vec![1.0], vec![2.0], vec![3.0]]).unwrap())
                    .unwrap()
            })
        };
        let b = {
            let s = Arc::clone(&session);
            std::thread::spawn(move || {
                s.run(Tensor::matrix(vec![vec![10.0], vec![20.0]]).unwrap()).unwrap()
            })
        };
        let ra = a.join().unwrap();
        let rb = b.join().unwrap();
        assert_eq!(ra[0].data(), &[2.0, 4.0, 6.0]);
        assert_eq!(rb[0].data(), &[20.0, 40.0]);
    }

    #[test]
    fn device_error_propagates_to_all_callers() {
        let sched = SharedBatchScheduler::<PendingRun>::new(SchedulerOptions::default());
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let runner = Arc::new(move |_input: Tensor| -> Result<Vec<Tensor>> {
            c.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("device on fire")
        });
        let session = BatchingSession::new(
            &sched,
            "s",
            SessionOptions {
                queue: QueueOptions {
                    max_batch_size: 4,
                    batch_timeout: Duration::from_millis(1),
                    max_enqueued_batches: 8,
                },
                allowed_batch_sizes: vec![4],
            },
            runner,
        );
        let err = session
            .run(Tensor::matrix(vec![vec![1.0]]).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("device on fire"));
    }

    #[test]
    fn oversized_request_rejected_with_hint() {
        let (_sched, session, _seen) = setup(SessionOptions {
            queue: QueueOptions { max_batch_size: 4, ..Default::default() },
            allowed_batch_sizes: vec![4],
        });
        let big = Tensor::zeros(vec![10, 1]);
        let err = session.run(big).unwrap_err();
        assert!(err.to_string().contains("splitter"), "{err}");
    }
}
