//! Workload generation and measurement for benches and examples.

pub mod workload;
