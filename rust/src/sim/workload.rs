//! Serving-benchmark workloads: open-loop (Poisson arrivals, the
//! standard for latency measurement — queueing effects included) and
//! closed-loop (N clients back-to-back, the standard for peak
//! throughput), plus a latency recorder.

use crate::util::metrics::Histogram;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Aggregated result of a run.
pub struct RunStats {
    pub requests: u64,
    pub errors: u64,
    pub elapsed: Duration,
    pub latency: Arc<Histogram>,
}

impl RunStats {
    pub fn qps(&self) -> f64 {
        self.requests as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:.0} qps ({} reqs, {} errs, {:.2}s) latency {}",
            self.qps(),
            self.requests,
            self.errors,
            self.elapsed.as_secs_f64(),
            self.latency.summary()
        )
    }
}

/// Closed loop: `threads` clients issue requests back-to-back for
/// `duration`. `op` returns Ok to count a success.
pub fn closed_loop<F>(threads: usize, duration: Duration, op: F) -> RunStats
where
    F: Fn(usize) -> anyhow::Result<()> + Send + Sync + 'static,
{
    let op = Arc::new(op);
    let latency = Arc::new(Histogram::new());
    let requests = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads.max(1))
        .map(|tid| {
            let op = Arc::clone(&op);
            let latency = Arc::clone(&latency);
            let requests = Arc::clone(&requests);
            let errors = Arc::clone(&errors);
            std::thread::spawn(move || {
                while t0.elapsed() < duration {
                    let s = Instant::now();
                    let ok = op(tid).is_ok();
                    latency.record_duration(s.elapsed());
                    requests.fetch_add(1, Ordering::Relaxed);
                    if !ok {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        let _ = h.join();
    }
    RunStats {
        requests: requests.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
        elapsed: t0.elapsed(),
        latency,
    }
}

/// Open loop: Poisson arrivals at `rate_qps` for `duration`, dispatched
/// onto `workers` threads through an unbounded queue. Latency includes
/// queueing (the honest tail).
pub fn open_loop<F>(rate_qps: f64, duration: Duration, workers: usize, seed: u64, op: F) -> RunStats
where
    F: Fn() -> anyhow::Result<()> + Send + Sync + 'static,
{
    use std::sync::mpsc;
    let op = Arc::new(op);
    let latency = Arc::new(Histogram::new());
    let requests = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    let (tx, rx) = mpsc::channel::<Instant>();
    let rx = Arc::new(std::sync::Mutex::new(rx));

    let handles: Vec<_> = (0..workers.max(1))
        .map(|_| {
            let op = Arc::clone(&op);
            let latency = Arc::clone(&latency);
            let requests = Arc::clone(&requests);
            let errors = Arc::clone(&errors);
            let rx = Arc::clone(&rx);
            std::thread::spawn(move || loop {
                let arrival = match rx.lock().unwrap().recv() {
                    Ok(a) => a,
                    Err(_) => return,
                };
                let ok = op().is_ok();
                // Latency from *arrival*, not from dispatch: includes
                // the time spent waiting for a free worker.
                latency.record_duration(arrival.elapsed());
                requests.fetch_add(1, Ordering::Relaxed);
                if !ok {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            })
        })
        .collect();

    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut next = t0;
    while t0.elapsed() < duration {
        let now = Instant::now();
        if now < next {
            std::thread::sleep(next - now);
        }
        let _ = tx.send(next);
        next += Duration::from_secs_f64(rng.exponential(1.0 / rate_qps));
    }
    drop(tx);
    for h in handles {
        let _ = h.join();
    }
    RunStats {
        requests: requests.load(Ordering::Relaxed),
        errors: errors.load(Ordering::Relaxed),
        elapsed: t0.elapsed(),
        latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closed_loop_counts_and_times() {
        let stats = closed_loop(4, Duration::from_millis(100), |_| {
            std::thread::sleep(Duration::from_micros(100));
            Ok(())
        });
        assert!(stats.requests > 100, "{}", stats.summary());
        assert_eq!(stats.errors, 0);
        assert!(stats.latency.quantile(0.5) >= 100_000); // >= 100us
        assert!(stats.qps() > 1000.0);
    }

    #[test]
    fn closed_loop_counts_errors() {
        let stats = closed_loop(2, Duration::from_millis(50), |tid| {
            if tid == 0 {
                anyhow::bail!("boom");
            }
            Ok(())
        });
        assert!(stats.errors > 0);
        assert!(stats.errors < stats.requests);
    }

    #[test]
    fn open_loop_rate_approximately_honored() {
        let stats = open_loop(2000.0, Duration::from_millis(500), 4, 42, || Ok(()));
        let rate = stats.requests as f64 / stats.elapsed.as_secs_f64();
        assert!(
            (rate - 2000.0).abs() < 400.0,
            "rate={rate} ({})",
            stats.summary()
        );
    }

    #[test]
    fn open_loop_latency_includes_queueing() {
        // 1 worker, 10ms service, arrivals at 200/s: heavy overload, so
        // tail latency must blow far past the 10ms service time.
        let stats = open_loop(200.0, Duration::from_millis(300), 1, 7, || {
            std::thread::sleep(Duration::from_millis(10));
            Ok(())
        });
        assert!(
            stats.latency.quantile(0.99) > 50_000_000,
            "{}",
            stats.summary()
        );
    }
}
