//! # tensorserve
//!
//! A Rust + JAX + Pallas reproduction of **TensorFlow-Serving: Flexible,
//! High-Performance ML Serving** (Olston et al., 2017).
//!
//! The crate mirrors the paper's three form factors:
//!
//! 1. **Library** — composable modules: model lifecycle management
//!    ([`lifecycle`]: Sources → Source Routers → Source Adapters →
//!    Loaders → Managers over the *aspired versions* API), inter-request
//!    batching ([`batching`]), and typed inference APIs ([`inference`]).
//! 2. **Canonical binary** — [`server`] assembles the vanilla
//!    file-system-source → HLO-adapter → `AspiredVersionsManager` stack
//!    behind an RPC front end (`tensorserve_server`).
//! 3. **Hosted service (TFS²)** — [`tfs2`]: Controller (bin-packing,
//!    transactional store), Synchronizer, Router (hedged requests),
//!    autoscaler, over an in-process multi-job cluster.
//!
//! Models are AOT-lowered by the build-time Python layer
//! (`python/compile/`): a JAX MLP whose dense layers run through a
//! Pallas kernel, exported as HLO text per (version, batch size) and
//! executed via the PJRT CPU client ([`runtime`]). Python is never on
//! the request path.
//!
//! The §2.1.2 performance machinery is faithful: wait-free RCU serving
//! maps ([`util::rcu`]), isolated load thread pools, reference-counted
//! handles whose final drop happens on a reclaim thread
//! ([`base::reclaim`]), `malloc_trim` on unload ([`util::mem`]), and
//! parallel initial load. See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod base;
pub mod batching;
pub mod http;
pub mod inference;
pub mod lifecycle;
pub mod net;
pub mod rpc;
pub mod runtime;
pub mod server;
pub mod serving;
pub mod sim;
pub mod tfs2;
pub mod util;

pub use base::servable::{ServableHandle, ServableId};
pub use lifecycle::manager::AspiredVersionsManager;
