//! Tiny CLI flag parser (`--flag=value` / `--flag value` / `--bool`).
//!
//! clap is not in the offline crate set; this covers what the canonical
//! binary, examples and benches need: typed flags with defaults, help
//! text, and unknown-flag errors.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct FlagSpec {
    help: String,
    default: String,
    is_bool: bool,
}

/// Declarative flag set.
///
/// ```no_run
/// # use tensorserve::util::argparse::Flags;
/// let mut f = Flags::new("demo", "a demo");
/// f.flag("port", "8500", "listen port");
/// f.bool_flag("verbose", "chatty output");
/// let parsed = f.parse(vec!["--port=9000".into(), "--verbose".into()]).unwrap();
/// assert_eq!(parsed.get_u64("port"), 9000);
/// assert!(parsed.get_bool("verbose"));
/// ```
pub struct Flags {
    program: String,
    about: String,
    specs: BTreeMap<String, FlagSpec>,
}

/// Parsed result: flag values + positional arguments.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Flags {
    pub fn new(program: &str, about: &str) -> Self {
        Flags { program: program.into(), about: about.into(), specs: BTreeMap::new() }
    }

    /// Declare a value flag with a default.
    pub fn flag(&mut self, name: &str, default: &str, help: &str) -> &mut Self {
        self.specs.insert(
            name.to_string(),
            FlagSpec { help: help.into(), default: default.into(), is_bool: false },
        );
        self
    }

    /// Declare a boolean flag (defaults to false).
    pub fn bool_flag(&mut self, name: &str, help: &str) -> &mut Self {
        self.specs.insert(
            name.to_string(),
            FlagSpec { help: help.into(), default: "false".into(), is_bool: true },
        );
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nFlags:\n", self.program, self.about);
        for (name, spec) in &self.specs {
            s.push_str(&format!(
                "  --{name}{}  {} (default: {})\n",
                if spec.is_bool { "" } else { "=<value>" },
                spec.help,
                spec.default
            ));
        }
        s
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, args: Vec<String>) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> =
            self.specs.iter().map(|(k, v)| (k.clone(), v.default.clone())).collect();
        let mut positional = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .get(&name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                let val = if spec.is_bool {
                    inline_val.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("flag --{name} needs a value"))?,
                    }
                };
                values.insert(name, val);
            } else {
                positional.push(arg);
            }
        }
        Ok(Parsed { values, positional })
    }

    /// Parse `std::env::args()`, printing usage and exiting on error.
    pub fn parse_or_exit(&self) -> Parsed {
        match self.parse(std::env::args().skip(1).collect()) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag {name} not declared"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("flag --{name} is not an integer: {}", self.get(name)))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get_u64(name) as usize
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("flag --{name} is not a number: {}", self.get(name)))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags() -> Flags {
        let mut f = Flags::new("t", "test");
        f.flag("port", "8500", "port");
        f.flag("name", "x", "name");
        f.bool_flag("verbose", "verbose");
        f
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults() {
        let p = flags().parse(vec![]).unwrap();
        assert_eq!(p.get_u64("port"), 8500);
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn inline_and_separate_values() {
        let p = flags().parse(args(&["--port=9000", "--name", "abc"])).unwrap();
        assert_eq!(p.get_u64("port"), 9000);
        assert_eq!(p.get("name"), "abc");
    }

    #[test]
    fn bool_flag_forms() {
        let p = flags().parse(args(&["--verbose"])).unwrap();
        assert!(p.get_bool("verbose"));
        let p = flags().parse(args(&["--verbose=false"])).unwrap();
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn positional_args() {
        let p = flags().parse(args(&["cmd", "--port=1", "extra"])).unwrap();
        assert_eq!(p.positional, vec!["cmd", "extra"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(flags().parse(args(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(flags().parse(args(&["--name"])).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = flags().parse(args(&["--help"])).unwrap_err();
        assert!(err.contains("--port"));
    }
}
