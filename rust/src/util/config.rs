//! Typed configuration over [`Json`](super::json::Json) documents.
//!
//! The canonical server binary reads a `model_config_list` file shaped
//! like TF-Serving's ModelServerConfig; [`Conf`] wraps a parsed JSON
//! value with path-based typed getters, defaults and error context.

use super::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// A configuration view over a JSON document.
#[derive(Debug, Clone)]
pub struct Conf {
    root: Json,
    origin: String,
}

impl Conf {
    pub fn from_json(root: Json, origin: &str) -> Self {
        Conf { root, origin: origin.to_string() }
    }

    pub fn parse(text: &str, origin: &str) -> Result<Self> {
        let root = Json::parse(text).with_context(|| format!("parsing {origin}"))?;
        Ok(Conf::from_json(root, origin))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, &path.display().to_string())
    }

    pub fn root(&self) -> &Json {
        &self.root
    }

    fn lookup(&self, path: &str) -> Result<&Json> {
        self.root
            .get_path(path)
            .ok_or_else(|| anyhow!("{}: missing key '{path}'", self.origin))
    }

    pub fn str(&self, path: &str) -> Result<&str> {
        self.lookup(path)?
            .as_str()
            .ok_or_else(|| anyhow!("{}: '{path}' is not a string", self.origin))
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.root.get_path(path).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn u64(&self, path: &str) -> Result<u64> {
        self.lookup(path)?
            .as_u64()
            .ok_or_else(|| anyhow!("{}: '{path}' is not a non-negative integer", self.origin))
    }

    pub fn u64_or(&self, path: &str, default: u64) -> u64 {
        self.root.get_path(path).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.root.get_path(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.root.get_path(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Array of sub-configs (e.g. `model_config_list`).
    pub fn list(&self, path: &str) -> Result<Vec<Conf>> {
        let arr = self
            .lookup(path)?
            .as_arr()
            .ok_or_else(|| anyhow!("{}: '{path}' is not an array", self.origin))?;
        Ok(arr
            .iter()
            .enumerate()
            .map(|(i, v)| Conf::from_json(v.clone(), &format!("{}:{path}[{i}]", self.origin)))
            .collect())
    }

    /// Validate that only known keys appear at the top level (catches
    /// typos in config files early, like TF-Serving's proto parsing).
    pub fn allow_keys(&self, keys: &[&str]) -> Result<()> {
        if let Some(obj) = self.root.as_obj() {
            for k in obj.keys() {
                if !keys.contains(&k.as_str()) {
                    bail!("{}: unknown key '{k}' (allowed: {keys:?})", self.origin);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "port": 8500,
      "batching": {"max_batch_size": 16, "timeout_ms": 2.5},
      "model_config_list": [
        {"name": "m1", "base_path": "/models/m1", "platform": "hlo"},
        {"name": "m2", "base_path": "/models/m2", "platform": "table"}
      ]
    }"#;

    #[test]
    fn typed_getters() {
        let c = Conf::parse(SAMPLE, "test").unwrap();
        assert_eq!(c.u64("port").unwrap(), 8500);
        assert_eq!(c.u64_or("batching.max_batch_size", 0), 16);
        assert_eq!(c.f64_or("batching.timeout_ms", 0.0), 2.5);
        assert_eq!(c.str_or("missing", "dflt"), "dflt");
        assert!(!c.bool_or("verbose", false));
    }

    #[test]
    fn list_of_models() {
        let c = Conf::parse(SAMPLE, "test").unwrap();
        let models = c.list("model_config_list").unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].str("name").unwrap(), "m1");
        assert_eq!(models[1].str("platform").unwrap(), "table");
    }

    #[test]
    fn missing_and_wrong_type_errors() {
        let c = Conf::parse(SAMPLE, "test").unwrap();
        assert!(c.str("port").is_err());
        assert!(c.u64("nope").is_err());
        assert!(c.list("port").is_err());
        let err = c.u64("nope.deep").unwrap_err().to_string();
        assert!(err.contains("nope.deep"), "{err}");
    }

    #[test]
    fn allow_keys_catches_typos() {
        let c = Conf::parse(r#"{"prot": 1}"#, "test").unwrap();
        assert!(c.allow_keys(&["port"]).is_err());
        assert!(c.allow_keys(&["prot", "port"]).is_ok());
    }
}
