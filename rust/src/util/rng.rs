//! Deterministic PRNG (SplitMix64 seeding a xoshiro256++ core).
//!
//! Everything stochastic in the repo — workload generators, property
//! tests, canary traffic sampling — draws from this so runs are
//! reproducible from a seed. No `rand` crate is available offline.

/// xoshiro256++ PRNG, seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic generator from a seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Seed from the OS clock — for non-test, non-benchmark paths only.
    pub fn from_entropy() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .as_nanos() as u64;
        Self::new(nanos ^ (std::process::id() as u64) << 32)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Exponentially distributed value with the given mean (for Poisson
    /// arrival processes in the workload generators).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = rng.next_below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn chance_extremes() {
        let mut rng = Rng::new(17);
        assert!(!(0..100).any(|_| rng.chance(0.0)));
        assert!((0..100).all(|_| rng.chance(1.0)));
    }
}
