//! quickcheck-lite: property testing with generation and shrinking.
//!
//! proptest is not in the offline crate set; this harness covers what
//! the invariant tests need — random structured inputs, failure
//! shrinking, deterministic seeds (`TS_CHECK_SEED`), case counts
//! (`TS_CHECK_CASES`).

use super::rng::Rng;
use std::fmt::Debug;

/// Values generatable from randomness with a size hint, and shrinkable
/// toward "smaller" counterexamples.
pub trait Arbitrary: Sized + Clone + Debug {
    fn arbitrary(g: &mut Rng, size: usize) -> Self;

    /// Candidate smaller values (tried in order during shrinking).
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

macro_rules! impl_arbitrary_uint {
    ($($t:ty),*) => {$(
        impl Arbitrary for $t {
            fn arbitrary(g: &mut Rng, size: usize) -> Self {
                // Mix small values (edge-case rich) with the full range.
                match g.next_below(4) {
                    0 => (g.next_below(8)) as $t,
                    1 => g.next_below((size.max(1) as u64).min(<$t>::MAX as u64) ) as $t,
                    _ => (g.next_u64() & (<$t>::MAX as u64)) as $t,
                }
            }
            fn shrink(&self) -> Vec<Self> {
                let mut out = Vec::new();
                if *self > 0 { out.push(0); }
                if *self > 1 { out.push(self / 2); out.push(self - 1); }
                out
            }
        }
    )*};
}

impl_arbitrary_uint!(u8, u16, u32, u64, usize);

impl Arbitrary for bool {
    fn arbitrary(g: &mut Rng, _size: usize) -> Self {
        g.next_below(2) == 1
    }
    fn shrink(&self) -> Vec<Self> {
        if *self { vec![false] } else { vec![] }
    }
}

impl Arbitrary for i64 {
    fn arbitrary(g: &mut Rng, size: usize) -> Self {
        let mag = u64::arbitrary(g, size) as i64 & i64::MAX;
        if bool::arbitrary(g, size) { -mag } else { mag }
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 { out.push(0); out.push(self / 2); }
        if *self < 0 { out.push(-self); }
        out
    }
}

impl Arbitrary for f64 {
    fn arbitrary(g: &mut Rng, _size: usize) -> Self {
        match g.next_below(5) {
            0 => 0.0,
            1 => 1.0,
            2 => -1.0,
            _ => (g.next_f64() - 0.5) * 2e6,
        }
    }
    fn shrink(&self) -> Vec<Self> {
        if *self != 0.0 { vec![0.0, self / 2.0] } else { vec![] }
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn arbitrary(g: &mut Rng, size: usize) -> Self {
        let len = g.next_below((size as u64).max(1)) as usize;
        (0..len).map(|_| T::arbitrary(g, size)).collect()
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(Vec::new());
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[1..].to_vec());
            // Shrink one element.
            for (i, x) in self.iter().enumerate().take(4) {
                for sx in x.shrink().into_iter().take(2) {
                    let mut v = self.clone();
                    v[i] = sx;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl Arbitrary for String {
    fn arbitrary(g: &mut Rng, size: usize) -> Self {
        let len = g.next_below((size as u64).max(1).min(64)) as usize;
        (0..len)
            .map(|_| {
                let c = g.next_below(96) as u8 + 32; // printable ascii
                c as char
            })
            .collect()
    }
    fn shrink(&self) -> Vec<Self> {
        if self.is_empty() {
            vec![]
        } else {
            vec![String::new(), self[..self.len() / 2].to_string()]
        }
    }
}

impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn arbitrary(g: &mut Rng, size: usize) -> Self {
        (A::arbitrary(g, size), B::arbitrary(g, size))
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary> Arbitrary for (A, B, C) {
    fn arbitrary(g: &mut Rng, size: usize) -> Self {
        (A::arbitrary(g, size), B::arbitrary(g, size), C::arbitrary(g, size))
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        out.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        out
    }
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run `prop` against `cases` random inputs; on failure, shrink and panic
/// with the minimal counterexample.
pub fn forall<T: Arbitrary, F: Fn(&T) -> bool>(label: &str, prop: F) {
    let cases = env_u64("TS_CHECK_CASES", 200);
    let seed = env_u64("TS_CHECK_SEED", 0xC0FFEE);
    let mut g = Rng::new(seed);
    for case in 0..cases {
        let size = (case as usize / 4 + 2).min(100);
        let input = T::arbitrary(&mut g, size);
        if !prop(&input) {
            let minimal = shrink_failure(input, &prop);
            panic!(
                "property '{label}' failed (case {case}, seed {seed}).\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_failure<T: Arbitrary, F: Fn(&T) -> bool>(mut failing: T, prop: &F) -> T {
    // Greedy descent, bounded to avoid pathological shrink graphs.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall::<(u32, u32), _>("add commutes", |(a, b)| {
            a.wrapping_add(*b) == b.wrapping_add(*a)
        });
    }

    #[test]
    fn vec_reverse_involution() {
        forall::<Vec<u16>, _>("reverse twice is identity", |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let result = std::panic::catch_unwind(|| {
            forall::<u64, _>("all values below 10", |x| *x < 10);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land on exactly 10.
        assert!(msg.contains("minimal counterexample: 10"), "{msg}");
    }

    #[test]
    fn string_generation_printable() {
        forall::<String, _>("strings are printable ascii", |s| {
            s.chars().all(|c| (' '..='\u{7f}').contains(&c))
        });
    }
}
