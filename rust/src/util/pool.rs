//! Size-class recycling pool for tensor storage buffers.
//!
//! The batching hot path allocates one device buffer per merged batch,
//! and the RPC layer allocates one buffer per decoded request tensor.
//! [`BufferPool`] shelves uniquely-owned `Arc<[T]>` allocations
//! (`T = f32` by default; an `i32` pool backs classifier class
//! outputs) in **power-of-two size classes** (floor [`MIN_CLASS`]
//! elements):
//! `acquire(len)` rounds up to the class and hands back any shelved
//! buffer of that class, so steady-state serving performs **zero**
//! buffer allocations on these paths. Classes rather than exact sizes
//! keep the shelf count tiny (≤ ~19 classes under the 64 MiB frame
//! cap) and make every recycled buffer reusable by every future
//! request — a client sweeping arbitrary tensor sizes cannot pin
//! unreusable shelves.
//!
//! ## Sharding (the contention fix)
//!
//! The shelves are **lock-striped across N shards** (N a power of two,
//! clamped to [`MAX_SHARDS`]; the global pools size N from the
//! machine's parallelism, overridable via
//! [`configure_global_shards`] before first use). Each thread is
//! assigned a home shard round-robin at first touch:
//! `acquire`/`release` take only that shard's mutex in the steady
//! state, so M request and batch threads hammering the pool no longer
//! serialize on one shelf lock. An `acquire` whose home shard is cold
//! falls through to the other shards (neighbor first) before
//! allocating fresh, so cross-thread flows — a device worker's output
//! buffer released later by a connection thread — still recycle
//! instead of chronically missing.
//!
//! Safety/uniqueness: a buffer is only shelved when the pool would be
//! its sole owner (`Arc::get_mut` succeeds), and an acquired buffer is
//! always uniquely owned, so callers may fill it via `Arc::get_mut`.
//! Contents of a recycled buffer are unspecified; acquirers must write
//! every element they expose (the assembly path writes rows + zeroes
//! the padding tail). Releases of non-class-sized buffers (anything
//! that didn't come from a pool) are declined, not shelved.
//!
//! Accounting: hit/miss/recycle counters, the buffers/bytes gauges and
//! the process-wide ledger in [`crate::util::mem::pooled_buffer_bytes`]
//! all **aggregate across shards**, so [`PoolStats`], the Status dump
//! and unload-time [`BufferPool::clear`] keep their single-shelf
//! semantics. The per-class buffer cap applies per shard (the byte cap
//! is pool-wide), which keeps the release path free of cross-shard
//! coordination.

use crate::util::metrics::Counter;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Smallest buffer class in elements (256 bytes): tiny tensors all
/// share one shelf instead of fragmenting into per-length shelves.
pub const MIN_CLASS: usize = 64;

/// Largest shard count a pool will stripe across; higher requests are
/// clamped (diminishing returns past the core count, and each shard
/// costs a mutex + map).
pub const MAX_SHARDS: usize = 64;

/// Round a requested element count up to its pool class.
pub fn size_class(len: usize) -> usize {
    len.next_power_of_two().max(MIN_CLASS)
}

/// Clamp a requested shard count into the supported range: at least 1,
/// at most [`MAX_SHARDS`], rounded up to a power of two (the shard
/// choice is a mask).
pub fn clamp_shards(n: usize) -> usize {
    n.clamp(1, MAX_SHARDS).next_power_of_two().min(MAX_SHARDS)
}

/// Default shard count: the next power of two ≥ the machine's
/// parallelism (≈ the number of threads that can contend), clamped.
fn default_shards() -> usize {
    clamp_shards(
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(8),
    )
}

/// Requested shard count for the global pools (0 = auto). Effective
/// only if set before the first `global()`/`global_i32()` touch.
static GLOBAL_SHARDS: AtomicUsize = AtomicUsize::new(0);

/// Set once the first global pool has been constructed (its shard
/// count is then fixed for the process lifetime).
static GLOBAL_BUILT: AtomicUsize = AtomicUsize::new(0);

fn global_shard_count() -> usize {
    GLOBAL_BUILT.store(1, Ordering::Release);
    match GLOBAL_SHARDS.load(Ordering::Relaxed) {
        0 => default_shards(),
        n => clamp_shards(n),
    }
}

/// Request a shard count for the **global** pools (`"batching":
/// {"pool_shards": N}` in the server config). Clamped via
/// [`clamp_shards`]; 0 restores auto-sizing. Returns `false` when a
/// global pool was already built — the request then has no effect and
/// callers should log rather than fail, since the pools work at any
/// shard count.
pub fn configure_global_shards(n: usize) -> bool {
    GLOBAL_SHARDS.store(n, Ordering::Relaxed);
    GLOBAL_BUILT.load(Ordering::Acquire) == 0
}

/// Counter snapshot for tests, the Status dump, and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Acquires served from a shelved buffer.
    pub hits: u64,
    /// Acquires that had to allocate fresh storage.
    pub misses: u64,
    /// Releases accepted onto a shelf.
    pub recycled: u64,
    /// Releases declined (buffer still shared, or pool at capacity).
    pub declined: u64,
    /// Buffers currently shelved (all shards).
    pub buffers_pooled: usize,
    /// Bytes currently shelved (all shards).
    pub bytes_pooled: usize,
}

/// One lock stripe: a mutex-guarded class → shelf map.
struct Shard<T> {
    shelves: Mutex<BTreeMap<usize, Vec<Arc<[T]>>>>,
}

pub struct BufferPool<T = f32> {
    shards: Vec<Shard<T>>,
    /// `shards.len() - 1` (shard count is a power of two).
    shard_mask: usize,
    /// Per-shard, per-class shelf cap.
    max_buffers_per_size: usize,
    /// Pool-wide byte cap (all shards together).
    max_total_bytes: usize,
    bytes_pooled: AtomicUsize,
    buffers_pooled: AtomicUsize,
    hits: Counter,
    misses: Counter,
    recycled: Counter,
    declined: Counter,
}

impl BufferPool<f32> {
    /// The process-wide f32 pool the serving stack shares (batch
    /// assembly, padding, RPC tensor decode).
    pub fn global() -> Arc<BufferPool> {
        static GLOBAL: once_cell::sync::Lazy<Arc<BufferPool>> =
            once_cell::sync::Lazy::new(|| {
                Arc::new(BufferPool::with_shards(32, 256 << 20, global_shard_count()))
            });
        Arc::clone(&GLOBAL)
    }
}

impl BufferPool<i32> {
    /// The process-wide i32 pool (classifier class outputs and decoded
    /// i32 wire tensors).
    pub fn global_i32() -> Arc<BufferPool<i32>> {
        static GLOBAL: once_cell::sync::Lazy<Arc<BufferPool<i32>>> =
            once_cell::sync::Lazy::new(|| {
                Arc::new(BufferPool::with_shards(32, 64 << 20, global_shard_count()))
            });
        Arc::clone(&GLOBAL)
    }
}

impl<T: Copy + Default + Send + Sync + 'static> BufferPool<T> {
    /// A pool striped across the default shard count.
    pub fn new(max_buffers_per_size: usize, max_total_bytes: usize) -> Self {
        Self::with_shards(max_buffers_per_size, max_total_bytes, default_shards())
    }

    /// A pool striped across `shards` lock shards (clamped via
    /// [`clamp_shards`]; 1 = the old single-mutex behavior, useful as a
    /// contention baseline in benches).
    pub fn with_shards(
        max_buffers_per_size: usize,
        max_total_bytes: usize,
        shards: usize,
    ) -> Self {
        let n = clamp_shards(shards);
        BufferPool {
            shards: (0..n)
                .map(|_| Shard { shelves: Mutex::new(BTreeMap::new()) })
                .collect(),
            shard_mask: n - 1,
            max_buffers_per_size,
            max_total_bytes,
            bytes_pooled: AtomicUsize::new(0),
            buffers_pooled: AtomicUsize::new(0),
            hits: Counter::default(),
            misses: Counter::default(),
            recycled: Counter::default(),
            declined: Counter::default(),
        }
    }

    /// Number of lock shards (diagnostics/benches).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// This thread's home shard index. Threads are numbered round-robin
    /// at first touch, so up to N pool-using threads get N distinct
    /// shards — batch workers and request threads stop sharing a lock.
    fn home_shard(&self) -> usize {
        thread_local! {
            static THREAD_TOKEN: usize = {
                static NEXT: AtomicUsize = AtomicUsize::new(0);
                NEXT.fetch_add(1, Ordering::Relaxed)
            };
        }
        THREAD_TOKEN.with(|t| *t) & self.shard_mask
    }

    /// Pop a buffer of `class` from shard `idx`, maintaining the
    /// aggregate accounting under that shard's lock (so a concurrent
    /// `clear()` can never interleave with it).
    fn pop_from_shard(&self, idx: usize, class: usize) -> Option<Arc<[T]>> {
        let mut shelves = self.shards[idx].shelves.lock().unwrap();
        let buf = shelves.get_mut(&class).and_then(Vec::pop)?;
        let bytes = class * std::mem::size_of::<T>();
        self.buffers_pooled.fetch_sub(1, Ordering::Relaxed);
        self.bytes_pooled.fetch_sub(bytes, Ordering::Relaxed);
        crate::util::mem::note_pool_bytes(-(bytes as i64));
        Some(buf)
    }

    /// A uniquely-owned buffer of **at least** `len` elements (rounded
    /// up to the size class). Served from the home shard's shelf when
    /// available, falling through to the remaining shards (neighbor
    /// first) before allocating fresh (zeroed) — so a buffer released
    /// by *any* thread is always found before paying an allocation,
    /// exactly like the pre-sharding single shelf. The steady state is
    /// a first-probe hit (one uncontended lock); the full sweep runs
    /// only on the way to what would otherwise be a miss. This matters
    /// because serving flows cross threads: device workers acquire
    /// output buffers that connection threads later release onto
    /// *their* home shards.
    pub fn acquire(&self, len: usize) -> Arc<[T]> {
        if len > 0 {
            let class = size_class(len);
            let home = self.home_shard();
            for probe in 0..self.shards.len() {
                if let Some(buf) = self.pop_from_shard((home + probe) & self.shard_mask, class)
                {
                    self.hits.inc();
                    debug_assert_eq!(Arc::strong_count(&buf), 1);
                    return buf;
                }
            }
            self.misses.inc();
            return std::iter::repeat(T::default()).take(class).collect();
        }
        self.misses.inc();
        std::iter::repeat(T::default()).take(len).collect()
    }

    /// Offer a buffer back. Shelved (on the caller's home shard) only
    /// if it is class-sized (i.e. pool-compatible), the pool would be
    /// its sole owner, and capacity limits allow; otherwise the Arc
    /// just drops.
    pub fn release(&self, mut buf: Arc<[T]>) {
        let len = buf.len();
        // Class + uniqueness gates: arbitrary-length buffers would
        // fragment the shelves, and a shared buffer may still back
        // live views.
        if len < MIN_CLASS || !len.is_power_of_two() || Arc::get_mut(&mut buf).is_none() {
            self.declined.inc();
            return;
        }
        let bytes = len * std::mem::size_of::<T>();
        if self.bytes_pooled.load(Ordering::Relaxed) + bytes > self.max_total_bytes {
            self.declined.inc();
            return;
        }
        let mut shelves = self.shards[self.home_shard()].shelves.lock().unwrap();
        let shelf = shelves.entry(len).or_default();
        if shelf.len() >= self.max_buffers_per_size {
            self.declined.inc();
            return;
        }
        shelf.push(buf);
        // Under the shard lock: a concurrent `clear()` must observe the
        // push and this accounting together or not at all.
        self.buffers_pooled.fetch_add(1, Ordering::Relaxed);
        self.bytes_pooled.fetch_add(bytes, Ordering::Relaxed);
        crate::util::mem::note_pool_bytes(bytes as i64);
        drop(shelves);
        self.recycled.inc();
    }

    /// Drop every shelved buffer on every shard (e.g. after servable
    /// unload, before `mem::release_to_os`).
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut shelves = shard.shelves.lock().unwrap();
            let bytes: usize = shelves
                .values()
                .flat_map(|v| v.iter())
                .map(|b| b.len() * std::mem::size_of::<T>())
                .sum();
            let count: usize = shelves.values().map(Vec::len).sum();
            shelves.clear();
            self.buffers_pooled.fetch_sub(count, Ordering::Relaxed);
            self.bytes_pooled.fetch_sub(bytes, Ordering::Relaxed);
            crate::util::mem::note_pool_bytes(-(bytes as i64));
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            recycled: self.recycled.get(),
            declined: self.declined.get(),
            buffers_pooled: self.buffers_pooled.load(Ordering::Relaxed),
            bytes_pooled: self.bytes_pooled.load(Ordering::Relaxed),
        }
    }

    /// Publish current pool state into a metrics registry (the server's
    /// Status dump calls this right before dumping).
    pub fn export(&self, registry: &crate::util::metrics::Registry, prefix: &str) {
        let s = self.stats();
        registry.gauge(&format!("{prefix}.hits")).set(s.hits as i64);
        registry.gauge(&format!("{prefix}.misses")).set(s.misses as i64);
        registry.gauge(&format!("{prefix}.recycled")).set(s.recycled as i64);
        registry.gauge(&format!("{prefix}.declined")).set(s.declined as i64);
        registry
            .gauge(&format!("{prefix}.buffers_pooled"))
            .set(s.buffers_pooled as i64);
        registry
            .gauge(&format!("{prefix}.bytes_pooled"))
            .set(s.bytes_pooled as i64);
        registry
            .gauge(&format!("{prefix}.shards"))
            .set(self.shard_count() as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_roundtrip() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        let a = pool.acquire(16);
        assert_eq!(a.len(), size_class(16)); // rounded up to the class
        assert!(a.len() >= 16);
        assert_eq!(pool.stats().misses, 1);
        let ptr = a.as_ptr();
        pool.release(a);
        assert_eq!(pool.stats().recycled, 1);
        assert_eq!(pool.stats().buffers_pooled, 1);
        let b = pool.acquire(16);
        assert_eq!(b.as_ptr(), ptr, "did not recycle the same allocation");
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().buffers_pooled, 0);
    }

    #[test]
    fn size_classes() {
        assert_eq!(size_class(1), MIN_CLASS);
        assert_eq!(size_class(MIN_CLASS), MIN_CLASS);
        assert_eq!(size_class(MIN_CLASS + 1), MIN_CLASS * 2);
        assert_eq!(size_class(100), 128);
        assert_eq!(size_class(128), 128);
    }

    #[test]
    fn shard_clamping() {
        assert_eq!(clamp_shards(0), 1);
        assert_eq!(clamp_shards(1), 1);
        assert_eq!(clamp_shards(3), 4);
        assert_eq!(clamp_shards(8), 8);
        assert_eq!(clamp_shards(1000), MAX_SHARDS);
        let pool: BufferPool = BufferPool::with_shards(4, 1 << 20, 5);
        assert_eq!(pool.shard_count(), 8);
        let single: BufferPool = BufferPool::with_shards(4, 1 << 20, 1);
        assert_eq!(single.shard_count(), 1);
    }

    #[test]
    fn classes_do_not_cross() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        pool.release(pool.acquire(8)); // class 64
        let b = pool.acquire(100); // class 128
        assert_eq!(b.len(), 128);
        assert_eq!(pool.stats().hits, 0, "wrong-class buffer handed out");
        // …but same-class different lengths share a shelf by design.
        let c = pool.acquire(3); // class 64 → hit
        assert_eq!(c.len(), 64);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn non_class_releases_declined() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        // A buffer that didn't come from a pool (arbitrary length).
        let odd: Arc<[f32]> = vec![0.0; 100].into();
        pool.release(odd);
        assert_eq!(pool.stats().buffers_pooled, 0);
        assert_eq!(pool.stats().declined, 1);
    }

    #[test]
    fn shared_buffers_declined() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        let a = pool.acquire(4);
        let clone = Arc::clone(&a);
        pool.release(a);
        assert_eq!(pool.stats().declined, 1);
        assert_eq!(pool.stats().buffers_pooled, 0);
        drop(clone);
    }

    #[test]
    fn capacity_limits_enforced() {
        let pool: BufferPool = BufferPool::new(2, 1 << 20);
        let bufs: Vec<_> = (0..3).map(|_| pool.acquire(4)).collect();
        for b in bufs {
            pool.release(b);
        }
        // Per-class shelf cap = 2 (all releases from this thread land
        // on its home shard): third release declined.
        assert_eq!(pool.stats().buffers_pooled, 2);
        assert_eq!(pool.stats().declined, 1);

        // Total-byte cap sized for exactly one MIN_CLASS buffer; the
        // cap is pool-wide (aggregated across shards).
        let tiny: BufferPool = BufferPool::new(8, MIN_CLASS * std::mem::size_of::<f32>());
        tiny.release(tiny.acquire(4));
        tiny.release(tiny.acquire(4));
        assert_eq!(tiny.stats().buffers_pooled, 1, "byte cap ignored");
    }

    #[test]
    fn zero_len_and_clear() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        let z = pool.acquire(0);
        assert_eq!(z.len(), 0);
        pool.release(z); // declined, not shelved
        assert_eq!(pool.stats().buffers_pooled, 0);
        pool.release(pool.acquire(8)); // class 64
        pool.release(pool.acquire(100)); // class 128
        assert_eq!(pool.stats().buffers_pooled, 2);
        pool.clear();
        let s = pool.stats();
        assert_eq!(s.buffers_pooled, 0);
        assert_eq!(s.bytes_pooled, 0);
    }

    #[test]
    fn acquired_buffers_are_unique_and_writable() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        pool.release(pool.acquire(4));
        let mut b = pool.acquire(4);
        let m = Arc::get_mut(&mut b).expect("pooled buffer not unique");
        m.fill(3.0);
        assert_eq!(&b[..4], &[3.0; 4]);
        assert_eq!(b.len(), MIN_CLASS);
    }

    #[test]
    fn i32_pool_recycles_like_f32() {
        let pool: BufferPool<i32> = BufferPool::new(4, 1 << 20);
        let a = pool.acquire(16);
        assert_eq!(a.len(), size_class(16));
        let ptr = a.as_ptr();
        pool.release(a);
        let b = pool.acquire(10); // same class
        assert_eq!(b.as_ptr(), ptr, "i32 pool did not recycle");
        assert_eq!(pool.stats().hits, 1);
        // The i32 global singleton constructs alongside the f32 one.
        let _ = BufferPool::global_i32();
        let _ = BufferPool::global();
    }

    // ----------------------------------------------- shard invariants

    /// Stats and the byte ledger must aggregate across shards: K
    /// threads (each homed on its own shard) release into the pool;
    /// the pool-wide snapshot sees all of them, and `clear()` empties
    /// every shard.
    #[test]
    fn stats_aggregate_across_shards_and_clear_empties_all() {
        let pool: Arc<BufferPool> = Arc::new(BufferPool::with_shards(8, 1 << 24, 8));
        const THREADS: usize = 8;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    // Miss, then shelve on this thread's home shard.
                    let buf = pool.acquire(256);
                    pool.release(buf);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.misses + s.hits, THREADS as u64);
        assert_eq!(s.recycled, THREADS as u64);
        // Hits can only come from a shared shard: shelved count is
        // releases minus re-acquisitions.
        assert_eq!(s.buffers_pooled as u64, THREADS as u64 - s.hits);
        assert_eq!(s.bytes_pooled, s.buffers_pooled * 256 * std::mem::size_of::<f32>());

        // Unload-path invariant: clear() empties every shard and the
        // aggregate accounting lands exactly on zero.
        pool.clear();
        let s = pool.stats();
        assert_eq!(s.buffers_pooled, 0, "clear() missed a shard");
        assert_eq!(s.bytes_pooled, 0);
    }

    /// A cold home shard falls through to the other shards (neighbor
    /// first) before allocating fresh, so cross-thread release flows
    /// still recycle.
    #[test]
    fn neighbor_fallthrough_reuses_other_shards_buffer() {
        let pool: Arc<BufferPool> = Arc::new(BufferPool::with_shards(8, 1 << 24, 2));
        // Fill BOTH shards from two fresh threads (tokens are assigned
        // round-robin, so two new threads land on distinct shards of a
        // 2-shard pool... in either order).
        let ptrs: Vec<_> = (0..2)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let buf = pool.acquire(512);
                    let p = buf.as_ptr() as usize;
                    pool.release(buf);
                    p
                })
                .join()
                .unwrap()
            })
            .collect();
        assert_eq!(pool.stats().buffers_pooled, 2);
        // Two acquires from this thread must find both (home + the
        // neighbor fallthrough), whatever this thread's home shard is.
        let a = pool.acquire(512);
        let b = pool.acquire(512);
        assert_eq!(pool.stats().hits, 2, "fallthrough missed a warm shard");
        assert!(ptrs.contains(&(a.as_ptr() as usize)));
        assert!(ptrs.contains(&(b.as_ptr() as usize)));
    }

    #[test]
    fn concurrent_acquire_release_is_consistent() {
        // M threads hammering acquire/release: accounting must balance
        // (the contended-path regression the sharding exists to serve).
        let pool: Arc<BufferPool> = Arc::new(BufferPool::with_shards(16, 1 << 26, 8));
        const THREADS: usize = 8;
        const OPS: usize = 500;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    for i in 0..OPS {
                        let buf = pool.acquire(64 << (i % 3));
                        std::hint::black_box(&buf);
                        pool.release(buf);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, (THREADS * OPS) as u64);
        assert_eq!(s.recycled as usize, s.buffers_pooled + s.hits as usize);
        pool.clear();
        assert_eq!(pool.stats().bytes_pooled, 0);
    }
}
