//! Size-class recycling pool for tensor storage buffers.
//!
//! The batching hot path allocates one device buffer per merged batch,
//! and the RPC layer allocates one buffer per decoded request tensor.
//! [`BufferPool`] shelves uniquely-owned `Arc<[T]>` allocations
//! (`T = f32` by default; an `i32` pool backs classifier class
//! outputs) in **power-of-two size classes** (floor [`MIN_CLASS`]
//! elements):
//! `acquire(len)` rounds up to the class and hands back any shelved
//! buffer of that class, so steady-state serving performs **zero**
//! buffer allocations on these paths. Classes rather than exact sizes
//! keep the shelf count tiny (≤ ~19 classes under the 64 MiB frame
//! cap) and make every recycled buffer reusable by every future
//! request — a client sweeping arbitrary tensor sizes cannot pin
//! unreusable shelves.
//!
//! Safety/uniqueness: a buffer is only shelved when the pool would be
//! its sole owner (`Arc::get_mut` succeeds), and an acquired buffer is
//! always uniquely owned, so callers may fill it via `Arc::get_mut`.
//! Contents of a recycled buffer are unspecified; acquirers must write
//! every element they expose (the assembly path writes rows + zeroes
//! the padding tail). Releases of non-class-sized buffers (anything
//! that didn't come from a pool) are declined, not shelved.
//!
//! Accounting: bytes shelved are tracked process-wide in
//! [`crate::util::mem::pooled_buffer_bytes`] (so RSS investigations can
//! subtract pool-held memory), and hit/miss/recycle counters use
//! [`crate::util::metrics::Counter`] for lock-free recording.

use crate::util::metrics::Counter;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Smallest buffer class in elements (256 bytes): tiny tensors all
/// share one shelf instead of fragmenting into per-length shelves.
pub const MIN_CLASS: usize = 64;

/// Round a requested element count up to its pool class.
pub fn size_class(len: usize) -> usize {
    len.next_power_of_two().max(MIN_CLASS)
}

/// Counter snapshot for tests, the Status dump, and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    /// Acquires served from a shelved buffer.
    pub hits: u64,
    /// Acquires that had to allocate fresh storage.
    pub misses: u64,
    /// Releases accepted onto a shelf.
    pub recycled: u64,
    /// Releases declined (buffer still shared, or pool at capacity).
    pub declined: u64,
    /// Buffers currently shelved.
    pub buffers_pooled: usize,
    /// Bytes currently shelved.
    pub bytes_pooled: usize,
}

pub struct BufferPool<T = f32> {
    shelves: Mutex<BTreeMap<usize, Vec<Arc<[T]>>>>,
    max_buffers_per_size: usize,
    max_total_bytes: usize,
    bytes_pooled: AtomicUsize,
    buffers_pooled: AtomicUsize,
    hits: Counter,
    misses: Counter,
    recycled: Counter,
    declined: Counter,
}

impl BufferPool<f32> {
    /// The process-wide f32 pool the serving stack shares (batch
    /// assembly, padding, RPC tensor decode).
    pub fn global() -> Arc<BufferPool> {
        static GLOBAL: once_cell::sync::Lazy<Arc<BufferPool>> =
            once_cell::sync::Lazy::new(|| Arc::new(BufferPool::new(32, 256 << 20)));
        Arc::clone(&GLOBAL)
    }
}

impl BufferPool<i32> {
    /// The process-wide i32 pool (classifier class outputs and decoded
    /// i32 wire tensors).
    pub fn global_i32() -> Arc<BufferPool<i32>> {
        static GLOBAL: once_cell::sync::Lazy<Arc<BufferPool<i32>>> =
            once_cell::sync::Lazy::new(|| Arc::new(BufferPool::new(32, 64 << 20)));
        Arc::clone(&GLOBAL)
    }
}

impl<T: Copy + Default + Send + Sync + 'static> BufferPool<T> {
    pub fn new(max_buffers_per_size: usize, max_total_bytes: usize) -> Self {
        BufferPool {
            shelves: Mutex::new(BTreeMap::new()),
            max_buffers_per_size,
            max_total_bytes,
            bytes_pooled: AtomicUsize::new(0),
            buffers_pooled: AtomicUsize::new(0),
            hits: Counter::default(),
            misses: Counter::default(),
            recycled: Counter::default(),
            declined: Counter::default(),
        }
    }

    /// A uniquely-owned buffer of **at least** `len` elements (rounded
    /// up to the size class). Served from the class shelf when
    /// available, else freshly allocated (zeroed). Recycled contents
    /// are unspecified — write before read.
    pub fn acquire(&self, len: usize) -> Arc<[T]> {
        if len > 0 {
            let class = size_class(len);
            // Counter updates stay inside the shelves lock so they can
            // never interleave with a concurrent `clear()`'s accounting.
            let mut shelves = self.shelves.lock().unwrap();
            if let Some(buf) = shelves.get_mut(&class).and_then(Vec::pop) {
                self.buffers_pooled.fetch_sub(1, Ordering::Relaxed);
                self.bytes_pooled
                    .fetch_sub(class * std::mem::size_of::<T>(), Ordering::Relaxed);
                crate::util::mem::note_pool_bytes(
                    -((class * std::mem::size_of::<T>()) as i64),
                );
                drop(shelves);
                self.hits.inc();
                debug_assert_eq!(Arc::strong_count(&buf), 1);
                return buf;
            }
            drop(shelves);
            self.misses.inc();
            return std::iter::repeat(T::default()).take(class).collect();
        }
        self.misses.inc();
        std::iter::repeat(T::default()).take(len).collect()
    }

    /// Offer a buffer back. Shelved only if it is class-sized (i.e.
    /// pool-compatible), the pool would be its sole owner, and capacity
    /// limits allow; otherwise the Arc just drops.
    pub fn release(&self, mut buf: Arc<[T]>) {
        let len = buf.len();
        // Class + uniqueness gates: arbitrary-length buffers would
        // fragment the shelves, and a shared buffer may still back
        // live views.
        if len < MIN_CLASS || !len.is_power_of_two() || Arc::get_mut(&mut buf).is_none() {
            self.declined.inc();
            return;
        }
        let bytes = len * std::mem::size_of::<T>();
        if self.bytes_pooled.load(Ordering::Relaxed) + bytes > self.max_total_bytes {
            self.declined.inc();
            return;
        }
        let mut shelves = self.shelves.lock().unwrap();
        let shelf = shelves.entry(len).or_default();
        if shelf.len() >= self.max_buffers_per_size {
            self.declined.inc();
            return;
        }
        shelf.push(buf);
        // Under the lock: a concurrent `clear()` must observe the push
        // and this accounting together or not at all.
        self.buffers_pooled.fetch_add(1, Ordering::Relaxed);
        self.bytes_pooled.fetch_add(bytes, Ordering::Relaxed);
        crate::util::mem::note_pool_bytes(bytes as i64);
        drop(shelves);
        self.recycled.inc();
    }

    /// Drop every shelved buffer (e.g. after servable unload, before
    /// `mem::release_to_os`).
    pub fn clear(&self) {
        let mut shelves = self.shelves.lock().unwrap();
        let bytes: usize = shelves
            .values()
            .flat_map(|v| v.iter())
            .map(|b| b.len() * std::mem::size_of::<T>())
            .sum();
        let count: usize = shelves.values().map(Vec::len).sum();
        shelves.clear();
        self.buffers_pooled.fetch_sub(count, Ordering::Relaxed);
        self.bytes_pooled.fetch_sub(bytes, Ordering::Relaxed);
        crate::util::mem::note_pool_bytes(-(bytes as i64));
        drop(shelves);
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            recycled: self.recycled.get(),
            declined: self.declined.get(),
            buffers_pooled: self.buffers_pooled.load(Ordering::Relaxed),
            bytes_pooled: self.bytes_pooled.load(Ordering::Relaxed),
        }
    }

    /// Publish current pool state into a metrics registry (the server's
    /// Status dump calls this right before dumping).
    pub fn export(&self, registry: &crate::util::metrics::Registry, prefix: &str) {
        let s = self.stats();
        registry.gauge(&format!("{prefix}.hits")).set(s.hits as i64);
        registry.gauge(&format!("{prefix}.misses")).set(s.misses as i64);
        registry.gauge(&format!("{prefix}.recycled")).set(s.recycled as i64);
        registry.gauge(&format!("{prefix}.declined")).set(s.declined as i64);
        registry
            .gauge(&format!("{prefix}.buffers_pooled"))
            .set(s.buffers_pooled as i64);
        registry
            .gauge(&format!("{prefix}.bytes_pooled"))
            .set(s.bytes_pooled as i64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_roundtrip() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        let a = pool.acquire(16);
        assert_eq!(a.len(), size_class(16)); // rounded up to the class
        assert!(a.len() >= 16);
        assert_eq!(pool.stats().misses, 1);
        let ptr = a.as_ptr();
        pool.release(a);
        assert_eq!(pool.stats().recycled, 1);
        assert_eq!(pool.stats().buffers_pooled, 1);
        let b = pool.acquire(16);
        assert_eq!(b.as_ptr(), ptr, "did not recycle the same allocation");
        assert_eq!(pool.stats().hits, 1);
        assert_eq!(pool.stats().buffers_pooled, 0);
    }

    #[test]
    fn size_classes() {
        assert_eq!(size_class(1), MIN_CLASS);
        assert_eq!(size_class(MIN_CLASS), MIN_CLASS);
        assert_eq!(size_class(MIN_CLASS + 1), MIN_CLASS * 2);
        assert_eq!(size_class(100), 128);
        assert_eq!(size_class(128), 128);
    }

    #[test]
    fn classes_do_not_cross() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        pool.release(pool.acquire(8)); // class 64
        let b = pool.acquire(100); // class 128
        assert_eq!(b.len(), 128);
        assert_eq!(pool.stats().hits, 0, "wrong-class buffer handed out");
        // …but same-class different lengths share a shelf by design.
        let c = pool.acquire(3); // class 64 → hit
        assert_eq!(c.len(), 64);
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn non_class_releases_declined() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        // A buffer that didn't come from a pool (arbitrary length).
        let odd: Arc<[f32]> = vec![0.0; 100].into();
        pool.release(odd);
        assert_eq!(pool.stats().buffers_pooled, 0);
        assert_eq!(pool.stats().declined, 1);
    }

    #[test]
    fn shared_buffers_declined() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        let a = pool.acquire(4);
        let clone = Arc::clone(&a);
        pool.release(a);
        assert_eq!(pool.stats().declined, 1);
        assert_eq!(pool.stats().buffers_pooled, 0);
        drop(clone);
    }

    #[test]
    fn capacity_limits_enforced() {
        let pool: BufferPool = BufferPool::new(2, 1 << 20);
        let bufs: Vec<_> = (0..3).map(|_| pool.acquire(4)).collect();
        for b in bufs {
            pool.release(b);
        }
        // Per-class shelf cap = 2: third release declined.
        assert_eq!(pool.stats().buffers_pooled, 2);
        assert_eq!(pool.stats().declined, 1);

        // Total-byte cap sized for exactly one MIN_CLASS buffer.
        let tiny: BufferPool = BufferPool::new(8, MIN_CLASS * std::mem::size_of::<f32>());
        tiny.release(tiny.acquire(4));
        tiny.release(tiny.acquire(4));
        assert_eq!(tiny.stats().buffers_pooled, 1, "byte cap ignored");
    }

    #[test]
    fn zero_len_and_clear() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        let z = pool.acquire(0);
        assert_eq!(z.len(), 0);
        pool.release(z); // declined, not shelved
        assert_eq!(pool.stats().buffers_pooled, 0);
        pool.release(pool.acquire(8)); // class 64
        pool.release(pool.acquire(100)); // class 128
        assert_eq!(pool.stats().buffers_pooled, 2);
        pool.clear();
        let s = pool.stats();
        assert_eq!(s.buffers_pooled, 0);
        assert_eq!(s.bytes_pooled, 0);
    }

    #[test]
    fn acquired_buffers_are_unique_and_writable() {
        let pool: BufferPool = BufferPool::new(4, 1 << 20);
        pool.release(pool.acquire(4));
        let mut b = pool.acquire(4);
        let m = Arc::get_mut(&mut b).expect("pooled buffer not unique");
        m.fill(3.0);
        assert_eq!(&b[..4], &[3.0; 4]);
        assert_eq!(b.len(), MIN_CLASS);
    }

    #[test]
    fn i32_pool_recycles_like_f32() {
        let pool: BufferPool<i32> = BufferPool::new(4, 1 << 20);
        let a = pool.acquire(16);
        assert_eq!(a.len(), size_class(16));
        let ptr = a.as_ptr();
        pool.release(a);
        let b = pool.acquire(10); // same class
        assert_eq!(b.as_ptr(), ptr, "i32 pool did not recycle");
        assert_eq!(pool.stats().hits, 1);
        // The i32 global singleton constructs alongside the f32 one.
        let _ = BufferPool::global_i32();
        let _ = BufferPool::global();
    }
}
