//! Read-copy-update cell with wait-free reads.
//!
//! TF-Serving §2.1.2: *"Read-copy-update data structure to ensure
//! wait-free access to servables by inference threads."* The serving map
//! (`ServableId → handle`) is read on every inference request and
//! written only when versions load/unload; a lock — even an uncontended
//! `RwLock` — puts an atomic RMW on the read path and lets a writer
//! stall the tail. This RCU gives readers a pin/unpin of one SeqCst
//! store each and **no stores shared with other readers** (per-thread
//! slots), so reads never wait and never bounce cache lines between
//! inference threads.
//!
//! Scheme: epoch-based reclamation.
//! * Readers pin by publishing the global epoch into a per-thread slot,
//!   then load the current pointer. Unpin clears the slot.
//! * Writers swap the pointer, bump the epoch, and retire the old value
//!   tagged with the pre-bump epoch. A retired value is freed once every
//!   pinned slot's epoch is newer than the retire tag (any reader that
//!   could still hold the old pointer pinned an older epoch).
//! * Reclamation is deferred and amortized onto later writes (and
//!   `drop`), so writers never block on readers either.
//!
//! Benchmarked against `Mutex`/`RwLock` maps in `benches/bench_rcu.rs`
//! (experiment T8) and exercised under contention by the tail-latency
//! bench (T2).

use std::cell::Cell;
use std::sync::atomic::{AtomicPtr, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Mutex};

const MAX_READERS: usize = 512;
const INACTIVE: u64 = 0;

/// Global registry of reader slots, shared by all `Rcu` instances.
///
/// One slot per thread, cache-line padded, claimed on first read and
/// released when the thread exits.
struct ReaderSlots {
    // Each slot is on its own cache line to stop reader-reader bouncing.
    slots: Vec<PaddedAtomicU64>,
}

#[repr(align(64))]
struct PaddedAtomicU64(AtomicU64);

static SLOTS: once_cell::sync::Lazy<ReaderSlots> = once_cell::sync::Lazy::new(|| {
    ReaderSlots {
        slots: (0..MAX_READERS)
            .map(|_| PaddedAtomicU64(AtomicU64::new(u64::MAX)))
            .collect(),
    }
});

// u64::MAX = slot free; INACTIVE(0) = claimed, not pinned; else pinned epoch.
const FREE: u64 = u64::MAX;

/// One past the highest slot index ever claimed. Writers scan only
/// `slots[..high_water]` instead of all `MAX_READERS` padded cache
/// lines: with the typical handful of reader threads, `update`/
/// `collect` touch a few lines, not 512. Monotonic (slot release does
/// not lower it), so a released-then-idle slot is still scanned — it
/// reads FREE, which the scan skips.
static HIGH_WATER: AtomicUsize = AtomicUsize::new(0);

struct SlotGuard(usize);

impl Drop for SlotGuard {
    fn drop(&mut self) {
        SLOTS.slots[self.0].0.store(FREE, SeqCst);
    }
}

thread_local! {
    static MY_SLOT: (SlotGuard, Cell<usize>) = {
        for (i, s) in SLOTS.slots.iter().enumerate() {
            if s.0
                .compare_exchange(FREE, INACTIVE, SeqCst, SeqCst)
                .is_ok()
            {
                HIGH_WATER.fetch_max(i + 1, SeqCst);
                return (SlotGuard(i), Cell::new(0));
            }
        }
        panic!("more than {MAX_READERS} concurrent RCU reader threads");
    };
}

/// The global epoch. Starts at 1 so `INACTIVE` (0) is never a valid pin.
static EPOCH: AtomicU64 = AtomicU64::new(1);

fn min_pinned_epoch() -> u64 {
    // SeqCst: pairs with the claim's fetch_max — a reader pinned in a
    // slot is claimed (and thus past its fetch_max) before it can hold
    // any pointer a writer might retire.
    let high = HIGH_WATER.load(SeqCst).min(MAX_READERS);
    let mut min = u64::MAX;
    for s in SLOTS.slots[..high].iter() {
        let v = s.0.load(SeqCst);
        if v != FREE && v != INACTIVE && v < min {
            min = v;
        }
    }
    min
}

/// Claimed-slot high-water mark (diagnostics/tests): number of slot
/// lines a writer scan currently covers.
pub fn reader_slot_high_water() -> usize {
    HIGH_WATER.load(SeqCst)
}

/// A cell holding a `T` readable wait-free and replaceable atomically.
pub struct Rcu<T: Send + Sync + 'static> {
    ptr: AtomicPtr<T>,
    retired: Mutex<Vec<(u64, *mut T)>>,
}

// `retired` raw pointers are owned boxes of T: Send + Sync.
unsafe impl<T: Send + Sync> Send for Rcu<T> {}
unsafe impl<T: Send + Sync> Sync for Rcu<T> {}

/// Pinned read guard; derefs to the value observed at pin time.
pub struct RcuGuard<'a, T: Send + Sync + 'static> {
    value: &'a T,
    slot: usize,
}

impl<'a, T: Send + Sync> std::ops::Deref for RcuGuard<'a, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.value
    }
}

impl<'a, T: Send + Sync> Drop for RcuGuard<'a, T> {
    fn drop(&mut self) {
        // Pin *count*, not a stack: guards may drop in any order.
        MY_SLOT.with(|(_, depth)| {
            let d = depth.get() - 1;
            depth.set(d);
            if d == 0 {
                SLOTS.slots[self.slot].0.store(INACTIVE, SeqCst);
            }
        });
    }
}

impl<T: Send + Sync + 'static> Rcu<T> {
    pub fn new(value: T) -> Self {
        Rcu {
            ptr: AtomicPtr::new(Box::into_raw(Box::new(value))),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Wait-free read: pin, load, return a guard.
    ///
    /// Reentrant: nested reads on the same thread reuse the outer pin
    /// (the slot keeps the *oldest* pinned epoch, which is the
    /// conservative one).
    pub fn read(&self) -> RcuGuard<'_, T> {
        MY_SLOT.with(|(slot, depth)| {
            let idx = slot.0;
            let d = depth.get();
            if d == 0 {
                // Publish our epoch *before* loading the pointer (SeqCst
                // total order makes the writer's scan see either our pin
                // or our load of the new pointer — see module docs).
                let e = EPOCH.load(SeqCst);
                SLOTS.slots[idx].0.store(e, SeqCst);
            }
            depth.set(d + 1);
            let p = self.ptr.load(SeqCst);
            RcuGuard {
                // Safety: p is live: it is only freed after every slot
                // pinned at/<= its retire epoch has unpinned, and we are
                // pinned at an epoch <= any subsequent retire.
                value: unsafe { &*p },
                slot: idx,
            }
        })
    }

    /// Clone the current value out (convenience for `T: Clone`).
    pub fn snapshot(&self) -> T
    where
        T: Clone,
    {
        self.read().clone()
    }

    /// Replace the value. Old value is retired and freed once no reader
    /// can still hold it. Never blocks on readers.
    pub fn update(&self, value: T) {
        let new = Box::into_raw(Box::new(value));
        let mut retired = self.retired.lock().unwrap();
        let old = self.ptr.swap(new, SeqCst);
        // Tag with the pre-bump epoch: readers pinned at <= this epoch
        // may hold `old`.
        let tag = EPOCH.fetch_add(1, SeqCst);
        retired.push((tag, old));
        Self::collect(&mut retired);
    }

    /// Read-modify-write convenience: build the new value from the old.
    pub fn rcu<F>(&self, f: F)
    where
        F: FnOnce(&T) -> T,
    {
        // Writers serialize on `retired`; read the current value inside
        // the critical section so updates are not lost.
        let mut retired = self.retired.lock().unwrap();
        let cur = self.ptr.load(SeqCst);
        let new = Box::into_raw(Box::new(f(unsafe { &*cur })));
        let old = self.ptr.swap(new, SeqCst);
        let tag = EPOCH.fetch_add(1, SeqCst);
        retired.push((tag, old));
        Self::collect(&mut retired);
    }

    fn collect(retired: &mut Vec<(u64, *mut T)>) {
        if retired.is_empty() {
            return;
        }
        let min = min_pinned_epoch();
        retired.retain(|&(tag, ptr)| {
            // A reader pinned at epoch e can hold pointers retired at
            // tag >= e. Free when every pinned epoch is > tag.
            if min > tag {
                drop(unsafe { Box::from_raw(ptr) });
                false
            } else {
                true
            }
        });
    }

    /// Number of retired-but-not-yet-freed values (for tests/metrics).
    pub fn pending_reclaim(&self) -> usize {
        self.retired.lock().unwrap().len()
    }

    /// Force a reclamation attempt.
    pub fn try_reclaim(&self) {
        Self::collect(&mut self.retired.lock().unwrap());
    }
}

impl<T: Send + Sync + 'static> Drop for Rcu<T> {
    fn drop(&mut self) {
        // Exclusive &mut self: no guards into this cell can exist
        // (guards borrow the Rcu), so everything can be freed.
        let cur = *self.ptr.get_mut();
        drop(unsafe { Box::from_raw(cur) });
        for (_, p) in self.retired.get_mut().unwrap().drain(..) {
            drop(unsafe { Box::from_raw(p) });
        }
    }
}

/// Shared-ownership RCU cell (what the serving map actually uses).
pub type SharedRcu<T> = Arc<Rcu<T>>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::sync::atomic::AtomicUsize;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn read_returns_current() {
        let cell = Rcu::new(7u32);
        assert_eq!(*cell.read(), 7);
        cell.update(9);
        assert_eq!(*cell.read(), 9);
    }

    #[test]
    fn guard_pins_old_value() {
        let cell = Rcu::new("a".to_string());
        let g = cell.read();
        cell.update("b".to_string());
        // Old value still valid through the guard.
        assert_eq!(&*g, "a");
        assert_eq!(cell.pending_reclaim(), 1);
        drop(g);
        cell.try_reclaim();
        assert_eq!(cell.pending_reclaim(), 0);
        assert_eq!(&*cell.read(), "b");
    }

    #[test]
    fn nested_reads_reentrant() {
        let cell = Rcu::new(1u64);
        let a = cell.read();
        let b = cell.read();
        assert_eq!(*a + *b, 2);
        drop(a);
        cell.update(5);
        assert_eq!(*b, 1, "outer pin still protects");
        drop(b);
        assert_eq!(*cell.read(), 5);
    }

    #[test]
    fn rcu_modify() {
        let cell = Rcu::new(vec![1, 2]);
        cell.rcu(|v| {
            let mut v = v.clone();
            v.push(3);
            v
        });
        assert_eq!(*cell.read(), vec![1, 2, 3]);
    }

    #[test]
    fn drop_frees_everything() {
        static LIVE: AtomicUsize = AtomicUsize::new(0);
        struct Counted;
        impl Counted {
            fn new() -> Self {
                LIVE.fetch_add(1, SeqCst);
                Counted
            }
        }
        impl Drop for Counted {
            fn drop(&mut self) {
                LIVE.fetch_sub(1, SeqCst);
            }
        }
        let cell = Rcu::new(Counted::new());
        for _ in 0..10 {
            cell.update(Counted::new());
        }
        drop(cell);
        assert_eq!(LIVE.load(SeqCst), 0);
    }

    #[test]
    fn concurrent_readers_and_writer() {
        let cell: SharedRcu<HashMap<u32, u32>> =
            Arc::new(Rcu::new((0..100).map(|i| (i, i)).collect()));
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let mut readers = Vec::new();
        for t in 0..8 {
            let cell = Arc::clone(&cell);
            let stop = Arc::clone(&stop);
            readers.push(thread::spawn(move || {
                let mut reads = 0u64;
                while !stop.load(SeqCst) {
                    let g = cell.read();
                    // Map is always internally consistent: v == k.
                    let k = (t * 13 + reads % 100) as u32 % 100;
                    assert_eq!(g.get(&k), Some(&k));
                    reads += 1;
                }
                reads
            }));
        }

        let writer = {
            let cell = Arc::clone(&cell);
            thread::spawn(move || {
                for _ in 0..200 {
                    cell.rcu(|m| m.clone());
                    thread::sleep(Duration::from_micros(100));
                }
            })
        };
        writer.join().unwrap();
        stop.store(true, SeqCst);
        for r in readers {
            assert!(r.join().unwrap() > 0);
        }
        // All threads unpinned: everything reclaims.
        cell.try_reclaim();
        assert_eq!(cell.pending_reclaim(), 0);
    }

    #[test]
    fn reclamation_is_bounded_under_reads() {
        let cell = Rcu::new(0usize);
        for i in 0..1000 {
            cell.update(i);
            let _g = cell.read();
        }
        cell.try_reclaim();
        assert_eq!(cell.pending_reclaim(), 0);
    }

    #[test]
    fn high_water_bounds_scan_and_grows_monotonically() {
        let cell = Rcu::new(0u8);
        let _ = cell.read(); // claims a slot on this thread
        let before = reader_slot_high_water();
        assert!(before >= 1 && before <= MAX_READERS);
        // More reader threads may only raise the mark.
        let handles: Vec<_> = (0..8)
            .map(|_| {
                thread::spawn(|| {
                    let c = Rcu::new(1u32);
                    assert_eq!(*c.read(), 1);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let after = reader_slot_high_water();
        assert!(after >= before, "high water regressed: {before} -> {after}");
        assert!(after <= MAX_READERS);
        // Reclamation still works with the bounded scan. (Retry: a
        // reader in a concurrently-running test may be pinned for a
        // moment; that defers frees but must not prevent them.)
        cell.update(9);
        for _ in 0..1000 {
            cell.try_reclaim();
            if cell.pending_reclaim() == 0 {
                break;
            }
            thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(cell.pending_reclaim(), 0);
        assert_eq!(*cell.read(), 9);
    }

    #[test]
    fn many_threads_slot_recycling() {
        // Threads exit and release their slots; spawning more threads
        // than MAX_READERS sequentially must not panic.
        for _ in 0..4 {
            let handles: Vec<_> = (0..64)
                .map(|_| {
                    thread::spawn(|| {
                        let cell = Rcu::new(1u8);
                        assert_eq!(*cell.read(), 1);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        }
    }
}
