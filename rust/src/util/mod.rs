//! Infrastructure substrates the serving stack is built on.
//!
//! The build environment has no network access, so everything that would
//! normally come from a crate (tokio, serde, clap, criterion, proptest,
//! arc-swap, …) is implemented here from scratch: a wait-free
//! read-copy-update cell ([`rcu`] — the §2.1.2 optimization), thread
//! pools ([`threadpool`]), metrics with log-bucketed histograms
//! ([`metrics`]), JSON ([`json`]), a virtual/real clock ([`clock`]),
//! deterministic PRNG ([`rng`]), a property-testing harness ([`check`]),
//! logging, CLI flags, OS-memory helpers ([`mem`]), and the size-keyed
//! tensor-storage recycling pool behind the zero-allocation batching
//! hot path ([`pool`]).

pub mod argparse;
pub mod bench;
pub mod check;
pub mod clock;
pub mod config;
pub mod fault;
pub mod json;
pub mod logging;
pub mod mem;
pub mod metrics;
pub mod pool;
pub mod rcu;
pub mod rng;
pub mod threadpool;
