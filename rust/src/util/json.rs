//! Minimal JSON: parse + serialize + typed accessors.
//!
//! serde is not in the offline crate set, so this hand-rolled module
//! backs everything that speaks JSON: model `spec.json` sidecars, the
//! BananaFlow table artifacts, server config files, and the TFS² store's
//! durable snapshots.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------- accessors

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|v| u64::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Member lookup (objects only).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Dotted-path lookup: `spec.get_path("input.shape")`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ------------------------------------------------------------ parsing

    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // -------------------------------------------------------- serializing

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/Inf have no JSON representation; emit null
                    // (what JSON.stringify does) rather than producing
                    // output no parser accepts.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------ number fast path
//
// Shared by `Parser::number` and the wire-codec SWAR ingress
// (`http::wire::simd`), so every number token decodes to bit-identical
// f64 values no matter which path touched it.

/// Powers of ten that are exactly representable in f64. With a mantissa
/// that is also exact (≤ 2^53), one multiply or divide by an entry is a
/// single correctly-rounded operation.
const POW10: [f64; 23] = [
    1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14, 1e15,
    1e16, 1e17, 1e18, 1e19, 1e20, 1e21, 1e22,
];

/// Clinger's fast path: compose `mantissa * 10^exp10` when both factors
/// are exactly representable, which makes the result bit-identical to
/// what `str::parse::<f64>` produces for the same token. Returns `None`
/// outside the exact window; callers must fall back to the full parser.
pub fn compose_f64_exact(mantissa: u64, exp10: i64) -> Option<f64> {
    if mantissa > (1u64 << 53) {
        return None;
    }
    let m = mantissa as f64;
    match exp10 {
        0 => Some(m),
        1..=22 => Some(m * POW10[exp10 as usize]),
        -22..=-1 => Some(m / POW10[(-exp10) as usize]),
        _ => None,
    }
}

/// Scan one JSON number at the head of `bytes` using exactly the
/// grammar `Parser::number` accepts: `-? digits* ('.' digits*)?
/// ([eE][+-]? digits*)?`. Returns the parsed value (or `None` when the
/// scanned text is not a number, e.g. `-` or `1e`) and the byte count
/// consumed. The common case composes the value without a string
/// round-trip; odd-but-valid spellings fall back to `str::parse`, so
/// results are bit-identical either way.
pub fn scan_number(bytes: &[u8]) -> (Option<f64>, usize) {
    let mut pos = 0usize;
    let neg = bytes.first() == Some(&b'-');
    if neg {
        pos += 1;
    }
    let mut mantissa: u64 = 0;
    let mut digits = 0usize;
    while let Some(c) = bytes.get(pos) {
        if !c.is_ascii_digit() {
            break;
        }
        mantissa = mantissa.wrapping_mul(10).wrapping_add((c - b'0') as u64);
        digits += 1;
        pos += 1;
    }
    let mut frac_digits: i64 = 0;
    if bytes.get(pos) == Some(&b'.') {
        pos += 1;
        while let Some(c) = bytes.get(pos) {
            if !c.is_ascii_digit() {
                break;
            }
            mantissa = mantissa.wrapping_mul(10).wrapping_add((c - b'0') as u64);
            digits += 1;
            frac_digits += 1;
            pos += 1;
        }
    }
    let mut exp: i64 = 0;
    let mut exp_digits = 0usize;
    let mut has_exp = false;
    let mut exp_neg = false;
    if matches!(bytes.get(pos), Some(b'e' | b'E')) {
        has_exp = true;
        pos += 1;
        if matches!(bytes.get(pos), Some(b'+' | b'-')) {
            exp_neg = bytes[pos] == b'-';
            pos += 1;
        }
        while let Some(c) = bytes.get(pos) {
            if !c.is_ascii_digit() {
                break;
            }
            exp = exp.saturating_mul(10).saturating_add((c - b'0') as i64);
            exp_digits += 1;
            pos += 1;
        }
    }
    // Fast compose: ≤ 19 digits means the mantissa accumulated without
    // wrapping; an exponent part must have digits to be valid at all.
    if digits >= 1 && digits <= 19 && (!has_exp || exp_digits > 0) {
        let e10 = (if exp_neg { -exp } else { exp }).saturating_sub(frac_digits);
        if let Some(v) = compose_f64_exact(mantissa, e10) {
            return (Some(if neg { -v } else { v }), pos);
        }
    }
    let text = std::str::from_utf8(&bytes[..pos]).unwrap();
    (text.parse::<f64>().ok(), pos)
}

/// Maximum container nesting the parser accepts. JSON is now
/// internet-facing (the REST gateway), so recursion depth is bounded
/// instead of letting `[[[[…` run the stack out.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, msg: msg.to_string() }
    }

    /// Bump the container depth; errors abort the whole parse, so the
    /// counter only needs decrementing on success exits.
    fn enter(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting deeper than 128 levels"));
        }
        Ok(())
    }

    /// Four hex digits starting at byte `at`.
    fn hex4(&self, at: usize) -> Result<u32, ParseError> {
        let raw = self
            .bytes
            .get(at..at + 4)
            .ok_or_else(|| self.err("bad \\u escape"))?;
        let hex = std::str::from_utf8(raw).map_err(|_| self.err("bad \\u escape"))?;
        u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            // self.pos is at the 'u'; hex follows.
                            let hi = self.hex4(self.pos + 1)?;
                            self.pos += 4;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: combine with a
                                // following \uXXXX low surrogate;
                                // a lone half decodes as U+FFFD.
                                let lo = if self.bytes.get(self.pos + 1) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2) == Some(&b'u')
                                {
                                    self.hex4(self.pos + 3).ok()
                                } else {
                                    None
                                };
                                match lo {
                                    Some(lo) if (0xDC00..0xE000).contains(&lo) => {
                                        self.pos += 6;
                                        let cp = 0x10000
                                            + ((hi - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(cp).unwrap_or('\u{fffd}')
                                    }
                                    _ => '\u{fffd}',
                                }
                            } else {
                                char::from_u32(hi).unwrap_or('\u{fffd}')
                            };
                            s.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let (value, consumed) = scan_number(&self.bytes[self.pos..]);
        self.pos += consumed;
        value.map(Json::Num).ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.enter()?;
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get_path("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,null,true],"name":"x \"y\"","nested":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "f": 3.5, "s": "x", "b": false}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("f").unwrap().as_i64(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(3.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        // NaN/Inf must never emit invalid JSON (the REST gateway
        // serializes model outputs straight onto the wire).
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let v = Json::Arr(vec![Json::Num(1.5), Json::Num(f64::NAN)]);
        assert_eq!(
            Json::parse(&v.to_string()).unwrap(),
            Json::Arr(vec![Json::Num(1.5), Json::Null])
        );
        // Large-but-finite values stay numeric.
        assert_eq!(Json::parse(&Json::Num(1e300).to_string()).unwrap(), Json::Num(1e300));
    }

    #[test]
    fn deep_nesting_rejected() {
        // One level under the guard parses…
        let deep = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&deep).is_ok());
        // …past it is an error, not a stack overflow. Mixed
        // array/object nesting counts against the same budget.
        let too_deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        let err = Json::parse(&too_deep).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        let objs = format!("{}1{}", "{\"k\":[".repeat(80), "]}".repeat(80));
        assert!(Json::parse(&objs).is_err());
    }

    #[test]
    fn truncated_inputs_error_cleanly() {
        for cut in [
            "{\"a\": [1,",
            "[[",
            "[1, 2",
            "\"abc",
            "\"ab\\",
            "\"ab\\u00",
            "{\"a\"",
            "{\"a\":",
            "-",
            "1e",
        ] {
            assert!(Json::parse(cut).is_err(), "accepted truncated {cut:?}");
        }
        // Truncating a real document at every byte must error, never
        // panic.
        let full = r#"{"a": [1, 2.5, "xé"], "b": {"c": true}}"#;
        for cut in 0..full.len() {
            if full.is_char_boundary(cut) {
                assert!(Json::parse(&full[..cut]).is_err(), "cut={cut}");
            }
        }
    }

    #[test]
    fn surrogate_pair_escapes() {
        // A surrogate pair decodes to one astral-plane scalar.
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("\u{1f600}"));
        // Lone halves decode as U+FFFD, never invalid UTF-8.
        assert_eq!(Json::parse(r#""\ud83dx""#).unwrap().as_str(), Some("\u{fffd}x"));
        assert_eq!(Json::parse(r#""\ude00""#).unwrap().as_str(), Some("\u{fffd}"));
        // High surrogate followed by a non-surrogate escape: the
        // second escape survives on its own.
        assert_eq!(
            Json::parse(r#""\ud83dA""#).unwrap().as_str(),
            Some("\u{fffd}A")
        );
        // BMP escapes still work.
        assert_eq!(Json::parse("\"\\u00e9\"").unwrap().as_str(), Some("é"));
    }

    #[test]
    fn scan_number_matches_std_parse_bit_for_bit() {
        for tok in [
            "0", "-0", "1", "42", "-7", "3.5", "-3.5", "0.1", "1.25", "1e3", "1E3", "1e+3",
            "1e-3", "-2.5e-2", "1.", "01", "-.5", "9007199254740993", "12345678901234567890",
            "1e300", "1e-300", "1e22", "1e23", "1e-22", "1e-23", "0.000123456789",
            "123456789.123456789", "1e999", "1e-999", "2.2250738585072011e-308",
        ] {
            let (got, consumed) = scan_number(tok.as_bytes());
            assert_eq!(consumed, tok.len(), "token {tok:?}");
            let want = tok.parse::<f64>().unwrap();
            assert_eq!(
                got.expect(tok).to_bits(),
                want.to_bits(),
                "token {tok:?}: fast={:?} std={want:?}",
                got
            );
        }
        // Invalid spellings report None after consuming the scan.
        for bad in ["-", "1e", "1.5e+", "-."] {
            let (got, consumed) = scan_number(bad.as_bytes());
            assert_eq!(consumed, bad.len(), "token {bad:?}");
            assert!(got.is_none(), "accepted {bad:?}");
        }
        // Scanning stops at the first non-number byte.
        let (got, consumed) = scan_number(b"12.5,3");
        assert_eq!((got, consumed), (Some(12.5), 4));
    }

    #[test]
    fn compose_f64_exact_window() {
        assert_eq!(compose_f64_exact(25, -1), Some(2.5));
        assert_eq!(compose_f64_exact(1, 22), Some(1e22));
        assert_eq!(compose_f64_exact(1, 23), None);
        assert_eq!(compose_f64_exact(1, -22), Some(1e-22));
        assert_eq!(compose_f64_exact(1, -23), None);
        assert_eq!(compose_f64_exact(1u64 << 53, 0), Some(9007199254740992.0));
        assert_eq!(compose_f64_exact((1u64 << 53) + 1, 0), None);
    }

    #[test]
    fn real_spec_json_parses() {
        // The exact shape aot.py emits.
        let spec = r#"{
          "platform": "hlo", "signature": "classify",
          "model_name": "mlp_classifier", "version": 2,
          "input": {"name": "x", "shape": [-1, 32], "dtype": "f32"},
          "outputs": [{"name": "log_probs", "shape": [-1, 4], "dtype": "f32"}],
          "allowed_batch_sizes": [1, 4, 16, 64],
          "ram_estimate_bytes": 1126932
        }"#;
        let v = Json::parse(spec).unwrap();
        assert_eq!(v.get_path("input.dtype").unwrap().as_str(), Some("f32"));
        let sizes: Vec<i64> = v
            .get("allowed_batch_sizes")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_i64().unwrap())
            .collect();
        assert_eq!(sizes, vec![1, 4, 16, 64]);
    }
}
