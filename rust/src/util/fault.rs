//! Deterministic fault injection for chaos testing (no PJRT, no
//! devices, no randomness).
//!
//! The serving stack consults named **fault points** at its failure
//! seams — `load:{model}` in the synthetic loader, `exec:{model}` in
//! [`HloServable::run`] — via [`hit`]. Tests (and operators, through
//! the `TENSORSERVE_FAULTS` env var) *arm* a point with a fault and a
//! count; each hit consumes one charge until the point runs dry, so
//! "fail twice then succeed" is exactly two armed charges. The
//! un-armed fast path is one relaxed atomic load — serving builds pay
//! nothing for carrying the hooks.
//!
//! Env syntax (parsed once at server start via [`arm_from_env`]):
//!
//! ```text
//! TENSORSERVE_FAULTS="load:mnist=fail:2;exec:mnist=delay:50ms:3"
//! ```
//!
//! — arm `load:mnist` to fail twice, and `exec:mnist` to sleep 50ms on
//! each of its next three executions.
//!
//! [`HloServable::run`]: crate::runtime::hlo_servable::HloServable

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What an armed point does on each charged hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Fault {
    /// Fail the operation with this message (kind-less: the consulting
    /// site decides how the failure classifies, same as a real fault).
    Fail { message: String },
    /// Latency spike: sleep this long, then let the operation proceed.
    Delay { duration: Duration },
}

struct Armed {
    fault: Fault,
    /// Charges left; the entry is removed when this reaches 0.
    times: u32,
}

/// Process-global registry. `ANY_ARMED` keeps the un-armed hot path to
/// a single relaxed load — the mutex is only touched while some point
/// is armed (tests / chaos runs).
static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<HashMap<String, Armed>>> = Mutex::new(None);

/// Arm `point` to apply `fault` on its next `times` hits. Re-arming a
/// point replaces its previous setting. `times == 0` disarms.
pub fn arm(point: &str, fault: Fault, times: u32) {
    let mut reg = REGISTRY.lock().unwrap();
    let map = reg.get_or_insert_with(HashMap::new);
    if times == 0 {
        map.remove(point);
    } else {
        map.insert(point.to_string(), Armed { fault, times });
    }
    ANY_ARMED.store(!map.is_empty(), Ordering::Release);
}

/// Disarm every point (test hygiene; also what a clean server start
/// does before applying its own config).
pub fn reset() {
    let mut reg = REGISTRY.lock().unwrap();
    *reg = None;
    ANY_ARMED.store(false, Ordering::Release);
}

/// Consult a fault point: no-op unless armed. A charged `Fail` returns
/// the armed error; a charged `Delay` sleeps, then returns `Ok`. Each
/// consult consumes one charge.
pub fn hit(point: &str) -> Result<()> {
    if !ANY_ARMED.load(Ordering::Acquire) {
        return Ok(());
    }
    let action = {
        let mut reg = REGISTRY.lock().unwrap();
        let Some(map) = reg.as_mut() else { return Ok(()) };
        let Some(armed) = map.get_mut(point) else { return Ok(()) };
        armed.times -= 1;
        let fault = armed.fault.clone();
        if armed.times == 0 {
            map.remove(point);
            ANY_ARMED.store(!map.is_empty(), Ordering::Release);
        }
        fault
    };
    match action {
        Fault::Fail { message } => bail!("injected fault at '{point}': {message}"),
        Fault::Delay { duration } => {
            std::thread::sleep(duration);
            Ok(())
        }
    }
}

/// Remaining charges on a point (tests/diagnostics).
pub fn charges(point: &str) -> u32 {
    let reg = REGISTRY.lock().unwrap();
    reg.as_ref()
        .and_then(|map| map.get(point))
        .map_or(0, |armed| armed.times)
}

/// Arm points from the `TENSORSERVE_FAULTS` env var, if set. Returns
/// the number of points armed. A malformed spec is an error — faults
/// silently not armed would make a chaos run vacuously green.
pub fn arm_from_env() -> Result<usize> {
    match std::env::var("TENSORSERVE_FAULTS") {
        Ok(spec) => arm_from_spec(&spec),
        Err(_) => Ok(0),
    }
}

/// Parse and arm a `point=fault[:arg]:times;...` spec (the env var's
/// format; also handy for tests).
pub fn arm_from_spec(spec: &str) -> Result<usize> {
    let mut armed = 0usize;
    for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
        let Some((point, action)) = entry.split_once('=') else {
            bail!("fault spec '{entry}': want point=action");
        };
        let parts: Vec<&str> = action.split(':').collect();
        let (fault, times) = match parts.as_slice() {
            ["fail", times] => (
                Fault::Fail { message: "armed via TENSORSERVE_FAULTS".into() },
                parse_times(entry, times)?,
            ),
            ["delay", dur, times] => (
                Fault::Delay { duration: parse_duration(entry, dur)? },
                parse_times(entry, times)?,
            ),
            _ => bail!("fault spec '{entry}': want fail:<times> or delay:<dur>:<times>"),
        };
        arm(point.trim(), fault, times);
        armed += 1;
    }
    Ok(armed)
}

fn parse_times(entry: &str, s: &str) -> Result<u32> {
    s.parse()
        .map_err(|_| anyhow::anyhow!("fault spec '{entry}': bad count '{s}'"))
}

fn parse_duration(entry: &str, s: &str) -> Result<Duration> {
    let (digits, unit) = s.split_at(s.find(|c: char| !c.is_ascii_digit()).unwrap_or(s.len()));
    let n: u64 = digits
        .parse()
        .map_err(|_| anyhow::anyhow!("fault spec '{entry}': bad duration '{s}'"))?;
    match unit {
        "ms" | "" => Ok(Duration::from_millis(n)),
        "s" => Ok(Duration::from_secs(n)),
        "us" => Ok(Duration::from_micros(n)),
        _ => bail!("fault spec '{entry}': unknown duration unit '{unit}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    // Tests share the process-global registry, so each uses unique
    // point names and never calls reset() (which would race siblings).

    #[test]
    fn unarmed_points_are_free() {
        assert!(hit("never:armed").is_ok());
        assert_eq!(charges("never:armed"), 0);
    }

    #[test]
    fn fail_charges_deplete() {
        arm("t:fail", Fault::Fail { message: "boom".into() }, 2);
        assert_eq!(charges("t:fail"), 2);
        let e = hit("t:fail").unwrap_err();
        assert!(e.to_string().contains("injected fault at 't:fail'"), "{e}");
        assert!(e.to_string().contains("boom"), "{e}");
        assert!(hit("t:fail").is_err());
        // Dry: back to a no-op.
        assert!(hit("t:fail").is_ok());
        assert_eq!(charges("t:fail"), 0);
    }

    #[test]
    fn delay_sleeps_then_proceeds() {
        arm("t:delay", Fault::Delay { duration: Duration::from_millis(20) }, 1);
        let t0 = Instant::now();
        assert!(hit("t:delay").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // Charge consumed: instant now.
        let t0 = Instant::now();
        assert!(hit("t:delay").is_ok());
        assert!(t0.elapsed() < Duration::from_millis(10));
    }

    #[test]
    fn rearm_replaces_and_zero_disarms() {
        arm("t:rearm", Fault::Fail { message: "a".into() }, 5);
        arm("t:rearm", Fault::Fail { message: "b".into() }, 1);
        assert_eq!(charges("t:rearm"), 1);
        let e = hit("t:rearm").unwrap_err();
        assert!(e.to_string().contains('b'), "{e}");
        arm("t:zero", Fault::Fail { message: "x".into() }, 3);
        arm("t:zero", Fault::Fail { message: "x".into() }, 0);
        assert!(hit("t:zero").is_ok());
    }

    #[test]
    fn spec_parsing() {
        let n = arm_from_spec("t:spec1=fail:2; t:spec2=delay:15ms:1").unwrap();
        assert_eq!(n, 2);
        assert_eq!(charges("t:spec1"), 2);
        assert_eq!(charges("t:spec2"), 1);
        assert!(hit("t:spec1").is_err());
        let t0 = Instant::now();
        assert!(hit("t:spec2").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(15));
        // Drain spec1's second charge so sibling tests stay isolated.
        assert!(hit("t:spec1").is_err());
        // Malformed specs are loud errors, not silent no-ops.
        assert!(arm_from_spec("nonsense").is_err());
        assert!(arm_from_spec("p=fail:notanumber").is_err());
        assert!(arm_from_spec("p=delay:10parsecs:1").is_err());
        assert!(arm_from_spec("p=explode:1").is_err());
        // Empty spec arms nothing.
        assert_eq!(arm_from_spec("").unwrap(), 0);
    }
}
