//! Counters, gauges and log-bucketed latency histograms.
//!
//! Every latency number this repo reports (benches, examples,
//! EXPERIMENTS.md) comes from [`Histogram`]: HdrHistogram-style
//! log-linear buckets — per power-of-two range, `SUB_BUCKETS` linear
//! sub-buckets — giving <= ~3% relative quantile error across ns..minutes
//! with a fixed 2.5KB footprint and lock-free recording.
//!
//! Cumulative series answer "what happened since boot"; the windowed
//! variants ([`WindowedCounter`], [`WindowedHistogram`]) answer "what
//! is happening *now*": two buckets rotate on a [`Clock`] interval, so
//! a read always covers between one and two intervals of history and a
//! burst from an hour ago can never pin today's p99. Health gates
//! (rollout engine, circuit breakers) and SLO-breach autoscaling read
//! the windowed series; `/metrics` keeps exporting both.

use crate::util::clock::{Clock, RealClock};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const SUB_BUCKET_BITS: u32 = 5; // 32 linear sub-buckets per octave
const SUB_BUCKETS: usize = 1 << SUB_BUCKET_BITS;
const OCTAVES: usize = 40; // covers [1, 2^40) ns ~= 18 minutes
const NBUCKETS: usize = OCTAVES * SUB_BUCKETS;

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value.
#[derive(Default)]
pub struct Gauge(AtomicI64);

impl std::fmt::Debug for Gauge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Gauge").field(&self.get()).finish()
    }
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Lock-free log-linear histogram of `u64` samples (nanoseconds by
/// convention).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn index(v: u64) -> usize {
        let v = v.max(1);
        let octave = (63 - v.leading_zeros()) as usize;
        if octave < SUB_BUCKET_BITS as usize {
            // Values below SUB_BUCKETS are exact.
            return v as usize;
        }
        let shift = octave as u32 - SUB_BUCKET_BITS;
        let sub = ((v >> shift) as usize) & (SUB_BUCKETS - 1);
        let oct_base = (octave - SUB_BUCKET_BITS as usize + 1) * SUB_BUCKETS;
        (oct_base + sub).min(NBUCKETS - 1)
    }

    /// Representative (upper-bound) value of bucket `i` — inverse of `index`.
    fn bucket_value(i: usize) -> u64 {
        if i < SUB_BUCKETS {
            return i as u64;
        }
        let octave = i / SUB_BUCKETS + SUB_BUCKET_BITS as usize - 1;
        let sub = i % SUB_BUCKETS;
        let shift = octave as u32 - SUB_BUCKET_BITS;
        (((SUB_BUCKETS + sub) as u64) << shift) | ((1u64 << shift) - 1)
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a `Duration` in nanoseconds.
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (the `_sum` of a summary metric).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Quantile in [0,1]; returns the upper bound of the containing
    /// bucket (<= ~3% relative error).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i).min(self.max());
            }
        }
        self.max()
    }

    /// (p50, p90, p99, p99.9) in nanoseconds.
    pub fn percentiles(&self) -> (u64, u64, u64, u64) {
        (
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.99),
            self.quantile(0.999),
        )
    }

    /// Zero every bucket and statistic. Only meaningful while no
    /// concurrent recorder is mid-`record` (the windowed rotator calls
    /// this under its rotation lock; a racing sample may land in the
    /// freshly-cleared bucket, which just makes the window fractionally
    /// wider — never wrong by more than one sample).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Merge counts of `other` into `self` (for per-thread recorders).
    pub fn merge(&self, other: &Histogram) {
        for (a, b) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                a.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count
            .fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum
            .fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max
            .fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Human summary, e.g. `n=100 mean=1.2ms p50=1.1ms p99=3.4ms max=5ms`.
    pub fn summary(&self) -> String {
        let (p50, p90, p99, p999) = self.percentiles();
        format!(
            "n={} mean={} p50={} p90={} p99={} p99.9={} max={}",
            self.count(),
            fmt_nanos(self.mean() as u64),
            fmt_nanos(p50),
            fmt_nanos(p90),
            fmt_nanos(p99),
            fmt_nanos(p999),
            fmt_nanos(self.max()),
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Pretty-print nanoseconds with an adaptive unit.
pub fn fmt_nanos(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.1}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Shared two-bucket rotation state: `epoch` is the window index
/// (`now / interval`) the *current* bucket belongs to; slot `epoch % 2`
/// is current, the other slot holds the previous full window. Readers
/// combine both, so a value covers 1–2 intervals of recent history.
struct Rotation {
    clock: Arc<dyn Clock>,
    interval_ns: u64,
    epoch: AtomicU64,
    lock: Mutex<()>,
}

impl Rotation {
    fn new(clock: Arc<dyn Clock>, interval: Duration) -> Self {
        Rotation {
            clock,
            interval_ns: (interval.as_nanos() as u64).max(1),
            epoch: AtomicU64::new(0),
            lock: Mutex::new(()),
        }
    }

    /// Advance to the current window if the clock moved past the
    /// recorded epoch, resetting whichever slots went stale. Returns
    /// the current slot index. `reset(i)` must clear slot `i`.
    fn advance(&self, reset: impl Fn(usize)) -> usize {
        let now = self.clock.now_nanos() / self.interval_ns;
        let seen = self.epoch.load(Ordering::Acquire);
        if now != seen {
            let _g = self.lock.lock().unwrap();
            let seen = self.epoch.load(Ordering::Acquire);
            if now == seen + 1 {
                // One interval elapsed: the slot about to become
                // current holds window `seen - 1` — stale, clear it.
                reset((now % 2) as usize);
                self.epoch.store(now, Ordering::Release);
            } else if now > seen {
                // Idle for 2+ intervals: everything is stale.
                reset(0);
                reset(1);
                self.epoch.store(now, Ordering::Release);
            }
        }
        (self.epoch.load(Ordering::Acquire) % 2) as usize
    }
}

/// Rolling counter: `sum()` reports events from the last 1–2 rotation
/// intervals instead of since boot. Backs recent error-rate gates.
pub struct WindowedCounter {
    rotation: Rotation,
    slots: [AtomicU64; 2],
}

impl WindowedCounter {
    pub fn new(clock: Arc<dyn Clock>, interval: Duration) -> Self {
        WindowedCounter {
            rotation: Rotation::new(clock, interval),
            slots: [AtomicU64::new(0), AtomicU64::new(0)],
        }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        let slot = self.rotation.advance(|i| self.slots[i].store(0, Ordering::Relaxed));
        self.slots[slot].fetch_add(n, Ordering::Relaxed);
    }

    /// Events recorded in the current + previous window.
    pub fn sum(&self) -> u64 {
        self.rotation.advance(|i| self.slots[i].store(0, Ordering::Relaxed));
        self.slots[0].load(Ordering::Relaxed) + self.slots[1].load(Ordering::Relaxed)
    }
}

/// Rolling histogram: quantiles/count/mean cover the last 1–2 rotation
/// intervals. The canonical source for "recent p99" — SLO autoscaling
/// and canary latency gates read this, never the cumulative series.
pub struct WindowedHistogram {
    rotation: Rotation,
    slots: [Histogram; 2],
}

impl WindowedHistogram {
    pub fn new(clock: Arc<dyn Clock>, interval: Duration) -> Self {
        WindowedHistogram {
            rotation: Rotation::new(clock, interval),
            slots: [Histogram::new(), Histogram::new()],
        }
    }

    pub fn record(&self, v: u64) {
        let slot = self.rotation.advance(|i| self.slots[i].reset());
        self.slots[slot].record(v);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as u64);
    }

    /// Materialize the rolling view (current + previous window) as a
    /// plain histogram for quantile reads. ~5KB of atomic loads — fine
    /// at scrape frequency, not meant for per-request paths.
    pub fn snapshot(&self) -> Histogram {
        self.rotation.advance(|i| self.slots[i].reset());
        let out = Histogram::new();
        out.merge(&self.slots[0]);
        out.merge(&self.slots[1]);
        out
    }

    pub fn quantile(&self, q: f64) -> u64 {
        self.snapshot().quantile(q)
    }

    pub fn count(&self) -> u64 {
        self.rotation.advance(|i| self.slots[i].reset());
        self.slots[0].count() + self.slots[1].count()
    }
}

/// Named metric registry, used by the server's `/metrics`-style dump.
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
    windowed_counters: Mutex<BTreeMap<String, Arc<WindowedCounter>>>,
    windowed_histograms: Mutex<BTreeMap<String, Arc<WindowedHistogram>>>,
    /// Clock + rotation interval for every windowed metric this
    /// registry creates (one knob per server: `metrics_window_ms`).
    clock: Arc<dyn Clock>,
    window: Duration,
}

impl Default for Registry {
    fn default() -> Self {
        Registry {
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(BTreeMap::new()),
            windowed_counters: Mutex::new(BTreeMap::new()),
            windowed_histograms: Mutex::new(BTreeMap::new()),
            clock: RealClock::shared(),
            window: Duration::from_secs(1),
        }
    }
}

impl Registry {
    pub fn new() -> Arc<Self> {
        Arc::new(Registry::default())
    }

    /// Registry whose windowed metrics rotate on `window` of `clock`
    /// (tests drive a `ManualClock` for deterministic windows).
    pub fn with_window(clock: Arc<dyn Clock>, window: Duration) -> Arc<Self> {
        Arc::new(Registry { clock, window, ..Registry::default() })
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            self.counters
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(
            self.gauges
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_default(),
        )
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut h = self.histograms.lock().unwrap();
        Arc::clone(
            h.entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new())),
        )
    }

    /// Rolling counter on this registry's window. Convention: name the
    /// series with a `.window` suffix (`…requests.window`) so readers
    /// can tell recent from cumulative at a glance.
    pub fn windowed_counter(&self, name: &str) -> Arc<WindowedCounter> {
        let mut w = self.windowed_counters.lock().unwrap();
        Arc::clone(w.entry(name.to_string()).or_insert_with(|| {
            Arc::new(WindowedCounter::new(Arc::clone(&self.clock), self.window))
        }))
    }

    /// Rolling histogram on this registry's window (same `.window`
    /// naming convention as [`Registry::windowed_counter`]).
    pub fn windowed_histogram(&self, name: &str) -> Arc<WindowedHistogram> {
        let mut w = self.windowed_histograms.lock().unwrap();
        Arc::clone(w.entry(name.to_string()).or_insert_with(|| {
            Arc::new(WindowedHistogram::new(Arc::clone(&self.clock), self.window))
        }))
    }

    /// Prometheus-style text exposition (what the HTTP gateway's
    /// `/metrics` endpoint serves): one `prefix_name value` line per
    /// counter/gauge, and a summary per histogram (`{quantile=…}`
    /// lines plus `_sum`/`_count`). Metric names are sanitized to
    /// `[a-zA-Z0-9_]` so dotted registry names ("rpc.predict.requests")
    /// become legal exposition names.
    pub fn render_prometheus(&self, prefix: &str) -> String {
        fn sanitize(name: &str) -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect()
        }
        let mut out = String::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {prefix}_{n} counter\n"));
            out.push_str(&format!("{prefix}_{n} {}\n", c.get()));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {prefix}_{n} gauge\n"));
            out.push_str(&format!("{prefix}_{n} {}\n", g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {prefix}_{n} summary\n"));
            for q in [0.5, 0.9, 0.99, 0.999] {
                out.push_str(&format!(
                    "{prefix}_{n}{{quantile=\"{q}\"}} {}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("{prefix}_{n}_sum {}\n", h.sum()));
            out.push_str(&format!("{prefix}_{n}_count {}\n", h.count()));
        }
        // Windowed series are non-monotonic by construction, so they
        // export as gauges/summaries regardless of what they count.
        for (k, c) in self.windowed_counters.lock().unwrap().iter() {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {prefix}_{n} gauge\n"));
            out.push_str(&format!("{prefix}_{n} {}\n", c.sum()));
        }
        for (k, w) in self.windowed_histograms.lock().unwrap().iter() {
            let n = sanitize(k);
            let h = w.snapshot();
            out.push_str(&format!("# TYPE {prefix}_{n} summary\n"));
            for q in [0.5, 0.9, 0.99, 0.999] {
                out.push_str(&format!(
                    "{prefix}_{n}{{quantile=\"{q}\"}} {}\n",
                    h.quantile(q)
                ));
            }
            out.push_str(&format!("{prefix}_{n}_sum {}\n", h.sum()));
            out.push_str(&format!("{prefix}_{n}_count {}\n", h.count()));
        }
        out
    }

    /// Structured samples, name-sorted: counters and gauges under
    /// their registry name, histograms expanded to `{name}.count`,
    /// `{name}.mean`, `{name}.p50`, `{name}.p99` and `{name}.max`.
    /// What `Request::Metrics` serves — the machine-readable surface
    /// the TFS² Synchronizer scrapes for autoscaling signals.
    pub fn samples(&self) -> Vec<(String, f64)> {
        let mut out = Vec::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push((k.clone(), c.get() as f64));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push((k.clone(), g.get() as f64));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push((format!("{k}.count"), h.count() as f64));
            out.push((format!("{k}.mean"), h.mean()));
            out.push((format!("{k}.p50"), h.quantile(0.5) as f64));
            out.push((format!("{k}.p99"), h.quantile(0.99) as f64));
            out.push((format!("{k}.max"), h.max() as f64));
        }
        for (k, c) in self.windowed_counters.lock().unwrap().iter() {
            out.push((k.clone(), c.sum() as f64));
        }
        for (k, w) in self.windowed_histograms.lock().unwrap().iter() {
            let h = w.snapshot();
            out.push((format!("{k}.count"), h.count() as f64));
            out.push((format!("{k}.mean"), h.mean()));
            out.push((format!("{k}.p50"), h.quantile(0.5) as f64));
            out.push((format!("{k}.p99"), h.quantile(0.99) as f64));
            out.push((format!("{k}.max"), h.max() as f64));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Text dump of everything (counters, gauges, histogram summaries).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", c.get()));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {k} {}\n", g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!("histogram {k} {}\n", h.summary()));
        }
        for (k, c) in self.windowed_counters.lock().unwrap().iter() {
            out.push_str(&format!("windowed_counter {k} {}\n", c.sum()));
        }
        for (k, w) in self.windowed_histograms.lock().unwrap().iter() {
            out.push_str(&format!("windowed_histogram {k} {}\n", w.snapshot().summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(10);
        g.add(-3);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histogram_exact_small_values() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 10, 31] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 31);
    }

    #[test]
    fn histogram_quantile_error_bounded() {
        let h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v * 1000); // 1us .. 100ms
        }
        for (q, want) in [(0.5, 50_000_000u64), (0.99, 99_000_000), (0.999, 99_900_000)] {
            let got = h.quantile(q);
            let err = (got as f64 - want as f64).abs() / want as f64;
            assert!(err < 0.05, "q={q} got={got} want={want} err={err}");
        }
    }

    #[test]
    fn histogram_mean_max() {
        let h = Histogram::new();
        h.record(10);
        h.record(20);
        h.record(60);
        assert_eq!(h.mean(), 30.0);
        assert_eq!(h.max(), 60);
    }

    #[test]
    fn histogram_merge() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(100);
        b.record(1000);
        b.record(2000);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 2000);
    }

    #[test]
    fn bucket_roundtrip_monotonic() {
        // index() must be monotonic in v and bucket_value(index(v)) >= v-ish
        let mut vs: Vec<u64> = (0..38)
            .flat_map(|exp| [0u64, 1, 7].map(|off| (1u64 << exp) + off))
            .collect();
        vs.sort_unstable();
        let mut last = 0usize;
        for v in vs {
            let i = Histogram::index(v);
            assert!(i >= last, "index not monotonic at {v}");
            last = i;
            let rep = Histogram::bucket_value(i);
            assert!(rep >= v, "rep {rep} < v {v}");
            if v >= 32 {
                assert!(
                    (rep as f64) / (v as f64) < 1.07,
                    "rep {rep} too far above {v}"
                );
            }
        }
    }

    #[test]
    fn registry_dedups() {
        let r = Registry::new();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").get(), 2);
        r.histogram("lat").record(5);
        let dump = r.dump();
        assert!(dump.contains("counter x 2"));
        assert!(dump.contains("histogram lat"));
    }

    #[test]
    fn samples_expand_histograms() {
        let r = Registry::new();
        r.counter("admission.shed").add(4);
        r.gauge("batch.m.lane_depth").set(6);
        for v in [10u64, 20, 30] {
            r.histogram("batch.m.queue_delay_ns").record(v);
        }
        let samples = r.samples();
        let get = |name: &str| {
            samples
                .iter()
                .find(|(k, _)| k == name)
                .unwrap_or_else(|| panic!("missing sample {name} in {samples:?}"))
                .1
        };
        assert_eq!(get("admission.shed"), 4.0);
        assert_eq!(get("batch.m.lane_depth"), 6.0);
        assert_eq!(get("batch.m.queue_delay_ns.count"), 3.0);
        assert_eq!(get("batch.m.queue_delay_ns.mean"), 20.0);
        assert_eq!(get("batch.m.queue_delay_ns.max"), 30.0);
        assert!(get("batch.m.queue_delay_ns.p99") >= 20.0);
        // Name-sorted for stable scraping.
        let names: Vec<&String> = samples.iter().map(|(k, _)| k).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn prometheus_exposition() {
        let r = Registry::new();
        r.counter("rpc.predict.requests").add(3);
        r.gauge("tensor_pool.bytes_pooled").set(-7);
        r.histogram("predict.batch_rows").record(8);
        r.histogram("predict.batch_rows").record(16);
        let text = r.render_prometheus("tensorserve");
        assert!(text.contains("# TYPE tensorserve_rpc_predict_requests counter\n"), "{text}");
        assert!(text.contains("tensorserve_rpc_predict_requests 3\n"), "{text}");
        assert!(text.contains("tensorserve_tensor_pool_bytes_pooled -7\n"), "{text}");
        assert!(text.contains("tensorserve_predict_batch_rows_count 2\n"), "{text}");
        assert!(text.contains("tensorserve_predict_batch_rows_sum 24\n"), "{text}");
        assert!(
            text.contains("tensorserve_predict_batch_rows{quantile=\"0.5\"} 8\n"),
            "{text}"
        );
        // Every line is either a comment or `name value...` with a
        // sanitized name.
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_whitespace()
                        .next()
                        .unwrap()
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric()
                            || c == '_'
                            || c == '{'
                            || c == '}'
                            || c == '='
                            || c == '"'
                            || c == '.'),
                "bad line {line:?}"
            );
        }
    }

    #[test]
    fn fmt_nanos_units() {
        assert_eq!(fmt_nanos(500), "500ns");
        assert_eq!(fmt_nanos(1500), "1.5us");
        assert_eq!(fmt_nanos(2_500_000), "2.50ms");
        assert_eq!(fmt_nanos(3_000_000_000), "3.00s");
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn histogram_reset_clears_everything() {
        let h = Histogram::new();
        h.record(100);
        h.record(1_000_000);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.quantile(0.99), 0);
        // Fully usable after a reset.
        h.record(7);
        assert_eq!(h.count(), 1);
        assert_eq!(h.max(), 7);
    }

    #[test]
    fn windowed_counter_forgets_old_windows() {
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new();
        let c = WindowedCounter::new(clock.clone(), Duration::from_secs(1));
        c.add(5);
        assert_eq!(c.sum(), 5);
        // One interval later: old window still visible (previous slot).
        clock.advance(Duration::from_secs(1));
        c.add(2);
        assert_eq!(c.sum(), 7);
        // Another interval: the first window's 5 rotates out.
        clock.advance(Duration::from_secs(1));
        assert_eq!(c.sum(), 2);
        // Long idle gap: everything rotates out.
        clock.advance(Duration::from_secs(10));
        assert_eq!(c.sum(), 0);
    }

    #[test]
    fn windowed_histogram_p99_reflects_recent_not_cumulative() {
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new();
        let w = WindowedHistogram::new(clock.clone(), Duration::from_secs(1));
        // A slow burst...
        for _ in 0..100 {
            w.record(1_000_000_000);
        }
        assert!(w.quantile(0.99) >= 900_000_000);
        // ...then two quiet intervals of fast traffic: the cumulative
        // p99 would still read ~1s, the windowed one recovers.
        clock.advance(Duration::from_secs(2));
        for _ in 0..100 {
            w.record(1_000);
        }
        assert!(w.quantile(0.99) < 10_000, "p99={}", w.quantile(0.99));
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn registry_exports_windowed_series() {
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new();
        let r = Registry::with_window(clock.clone(), Duration::from_secs(1));
        r.windowed_counter("health.m.v2.errors.window").add(3);
        r.windowed_histogram("health.m.v2.latency_ns.window").record(40);
        r.counter("health.m.v2.total").inc();
        let samples = r.samples();
        let get = |name: &str| {
            samples
                .iter()
                .find(|(k, _)| k == name)
                .unwrap_or_else(|| panic!("missing sample {name} in {samples:?}"))
                .1
        };
        assert_eq!(get("health.m.v2.errors.window"), 3.0);
        assert_eq!(get("health.m.v2.latency_ns.window.count"), 1.0);
        assert_eq!(get("health.m.v2.latency_ns.window.max"), 40.0);
        // Name-sorted alongside everything else.
        let names: Vec<&String> = samples.iter().map(|(k, _)| k).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        // After the window rotates out, the samples read zero but stay
        // present (scrapers see a quiet series, not a vanished one).
        clock.advance(Duration::from_secs(3));
        let samples = r.samples();
        let get = |name: &str| samples.iter().find(|(k, _)| k == name).unwrap().1;
        assert_eq!(get("health.m.v2.errors.window"), 0.0);
        assert_eq!(get("health.m.v2.latency_ns.window.count"), 0.0);
        // Prometheus exposition carries them as gauge/summary.
        let text = r.render_prometheus("ts");
        assert!(text.contains("# TYPE ts_health_m_v2_errors_window gauge\n"), "{text}");
        assert!(text.contains("ts_health_m_v2_latency_ns_window_count 0\n"), "{text}");
    }
}
