//! Shared micro-benchmark harness for `benches/` (criterion is not in
//! the offline crate set). Provides warmup+measure loops and aligned
//! table output so every bench prints paper-style rows.

use std::time::{Duration, Instant};

/// Run `f` for ~`duration` after a warmup, returning (iterations, elapsed).
pub fn measure<F: FnMut()>(warmup: Duration, duration: Duration, mut f: F) -> (u64, Duration) {
    let w0 = Instant::now();
    while w0.elapsed() < warmup {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed() < duration {
        f();
        iters += 1;
    }
    (iters, t0.elapsed())
}

/// Nanoseconds per iteration from a `measure` result.
pub fn ns_per_iter(iters: u64, elapsed: Duration) -> f64 {
    elapsed.as_nanos() as f64 / iters.max(1) as f64
}

/// Aligned ASCII table, one per experiment.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Print the table (benches call this at the end of each section).
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("--"));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Format a f64 with thousands separators (qps columns).
pub fn fmt_count(x: f64) -> String {
    let v = x.round() as i64;
    let s = v.abs().to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if v < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let (iters, elapsed) = measure(
            Duration::from_millis(1),
            Duration::from_millis(20),
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(iters > 1000);
        assert!(elapsed >= Duration::from_millis(20));
        assert!(ns_per_iter(iters, elapsed) < 100_000.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // just must not panic
    }

    #[test]
    fn fmt_count_separators() {
        assert_eq!(fmt_count(1234567.0), "1,234,567");
        assert_eq!(fmt_count(999.0), "999");
        assert_eq!(fmt_count(1000.0), "1,000");
    }
}
