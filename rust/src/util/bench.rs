//! Shared micro-benchmark harness for `benches/` (criterion is not in
//! the offline crate set). Provides warmup+measure loops and aligned
//! table output so every bench prints paper-style rows.

use std::time::{Duration, Instant};

/// True when benches should run one short smoke iteration instead of a
/// full measurement — set by `scripts/check.sh --bench-smoke`
/// (`TENSORSERVE_BENCH_SMOKE=1`) as a compile-and-run guard so benches
/// cannot silently rot. Numbers produced in smoke mode are meaningless;
/// only completion matters.
pub fn smoke() -> bool {
    smoke_from(std::env::var("TENSORSERVE_BENCH_SMOKE").ok().as_deref())
}

/// Pure core of [`smoke`] (unit-testable without mutating the process
/// environment, which is UB to race with `getenv`).
fn smoke_from(value: Option<&str>) -> bool {
    matches!(value, Some(v) if v != "0" && !v.is_empty())
}

/// A bench's measurement window: `full` normally, clipped to ~100ms in
/// smoke mode. Route every top-level bench duration through this.
pub fn bench_duration(full: Duration) -> Duration {
    clip_duration(full, smoke())
}

/// Pure core of [`bench_duration`].
fn clip_duration(full: Duration, smoke: bool) -> Duration {
    if smoke {
        full.min(Duration::from_millis(100))
    } else {
        full
    }
}

/// Write a bench's machine-readable trajectory file — unless in smoke
/// mode, whose numbers are meaningless: `make check` must never
/// overwrite committed BENCH_*.json with 100ms-clipped measurements.
pub fn write_bench_json(path: &str, contents: &str) {
    if smoke() {
        println!("\nsmoke mode: not overwriting {path}");
        return;
    }
    match std::fs::write(path, contents) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}

/// Run `f` for ~`duration` after a warmup, returning (iterations, elapsed).
pub fn measure<F: FnMut()>(warmup: Duration, duration: Duration, mut f: F) -> (u64, Duration) {
    let w0 = Instant::now();
    while w0.elapsed() < warmup {
        f();
    }
    let t0 = Instant::now();
    let mut iters = 0u64;
    while t0.elapsed() < duration {
        f();
        iters += 1;
    }
    (iters, t0.elapsed())
}

/// Nanoseconds per iteration from a `measure` result.
pub fn ns_per_iter(iters: u64, elapsed: Duration) -> f64 {
    elapsed.as_nanos() as f64 / iters.max(1) as f64
}

/// Aligned ASCII table, one per experiment.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Print the table (benches call this at the end of each section).
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("--"));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

/// Format a f64 with thousands separators (qps columns).
pub fn fmt_count(x: f64) -> String {
    let v = x.round() as i64;
    let s = v.abs().to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if v < 0 {
        format!("-{out}")
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iterations() {
        let (iters, elapsed) = measure(
            Duration::from_millis(1),
            Duration::from_millis(20),
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(iters > 1000);
        assert!(elapsed >= Duration::from_millis(20));
        assert!(ns_per_iter(iters, elapsed) < 100_000.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new("t", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print(); // just must not panic
    }

    #[test]
    fn bench_duration_clips_in_smoke_mode() {
        // Pure helpers only: mutating the real environment races other
        // threads' getenv (UB), so the env read stays untested here.
        assert!(smoke_from(Some("1")));
        assert!(smoke_from(Some("yes")));
        assert!(!smoke_from(Some("0")));
        assert!(!smoke_from(Some("")));
        assert!(!smoke_from(None));
        assert_eq!(
            clip_duration(Duration::from_secs(5), true),
            Duration::from_millis(100)
        );
        assert_eq!(
            clip_duration(Duration::from_millis(20), true),
            Duration::from_millis(20)
        );
        assert_eq!(
            clip_duration(Duration::from_secs(5), false),
            Duration::from_secs(5)
        );
    }

    #[test]
    fn fmt_count_separators() {
        assert_eq!(fmt_count(1234567.0), "1,234,567");
        assert_eq!(fmt_count(999.0), "999");
        assert_eq!(fmt_count(1000.0), "1,000");
    }
}
