//! Pluggable time: a [`Clock`] trait with a real implementation and a
//! manually-advanced one.
//!
//! Lifecycle polling, batch timeouts, hedging delays and the workload
//! generators all take a `Arc<dyn Clock>` so integration tests and the
//! transition-policy benches can run on deterministic virtual time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A monotonic clock measured in nanoseconds from an arbitrary origin.
pub trait Clock: Send + Sync {
    /// Nanoseconds since the clock's origin.
    fn now_nanos(&self) -> u64;

    /// Block the calling thread for `d` (of *this clock's* time).
    fn sleep(&self, d: Duration);

    /// Current time as a `Duration` from origin.
    fn now(&self) -> Duration {
        Duration::from_nanos(self.now_nanos())
    }
}

/// Wall-clock time via `std::time::Instant`.
pub struct RealClock {
    origin: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock { origin: Instant::now() }
    }

    /// Shared default real clock.
    pub fn shared() -> Arc<dyn Clock> {
        Arc::new(RealClock::new())
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now_nanos(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Virtual time advanced explicitly by tests/benches.
///
/// `sleep` blocks until another thread calls [`ManualClock::advance`]
/// far enough. This gives deterministic schedules to anything built on
/// timeouts (batch timeout, source polling, hedging).
pub struct ManualClock {
    nanos: AtomicU64,
    lock: Mutex<()>,
    cond: Condvar,
}

impl ManualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(ManualClock {
            nanos: AtomicU64::new(0),
            lock: Mutex::new(()),
            cond: Condvar::new(),
        })
    }

    /// Move time forward and wake all sleepers.
    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
        let _g = self.lock.lock().unwrap();
        self.cond.notify_all();
    }

    /// Set absolute time (must be monotonic).
    pub fn set_nanos(&self, t: u64) {
        let prev = self.nanos.swap(t, Ordering::SeqCst);
        assert!(t >= prev, "ManualClock must advance monotonically");
        let _g = self.lock.lock().unwrap();
        self.cond.notify_all();
    }
}

impl Clock for ManualClock {
    fn now_nanos(&self) -> u64 {
        self.nanos.load(Ordering::SeqCst)
    }

    fn sleep(&self, d: Duration) {
        let deadline = self.now_nanos() + d.as_nanos() as u64;
        let mut g = self.lock.lock().unwrap();
        while self.now_nanos() < deadline {
            // Real-time cap so a forgotten `advance` cannot hang a test
            // forever; virtual waiting resumes on each notify.
            let (ng, timeout) = self
                .cond
                .wait_timeout(g, Duration::from_secs(30))
                .unwrap();
            g = ng;
            if timeout.timed_out() {
                panic!("ManualClock::sleep timed out waiting for advance()");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn real_clock_advances() {
        let c = RealClock::new();
        let a = c.now_nanos();
        c.sleep(Duration::from_millis(2));
        assert!(c.now_nanos() > a);
    }

    #[test]
    fn manual_clock_advance() {
        let c = ManualClock::new();
        assert_eq!(c.now_nanos(), 0);
        c.advance(Duration::from_secs(5));
        assert_eq!(c.now(), Duration::from_secs(5));
    }

    #[test]
    fn manual_clock_sleep_wakes_on_advance() {
        let c = ManualClock::new();
        let c2 = Arc::clone(&c);
        let h = thread::spawn(move || {
            c2.sleep(Duration::from_millis(100));
            c2.now_nanos()
        });
        // give the sleeper a moment to block, then advance
        thread::sleep(Duration::from_millis(10));
        c.advance(Duration::from_millis(50));
        thread::sleep(Duration::from_millis(10));
        c.advance(Duration::from_millis(60));
        assert!(h.join().unwrap() >= 100_000_000);
    }

    #[test]
    #[should_panic(expected = "monotonically")]
    fn manual_clock_rejects_backwards() {
        let c = ManualClock::new();
        c.set_nanos(10);
        c.set_nanos(5);
    }
}
