//! Leveled logging to stderr with a global level from `TS_LOG`.
//!
//! `TS_LOG=debug|info|warn|error|off` (default `info`).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn level_from_env() -> Level {
    match std::env::var("TS_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        Ok("off") => Level::Off,
        _ => Level::Info,
    }
}

/// Current log level (lazy-initialized from env).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != u8::MAX {
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let l = level_from_env();
    LEVEL.store(l as u8, Ordering::Relaxed);
    l
}

/// Override the level programmatically (tests, benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    l >= level()
}

#[doc(hidden)]
pub fn emit(l: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
        Level::Off => return,
    };
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default();
    eprintln!("{tag} {:>10.3}s {module}: {args}", t.as_secs_f64() % 100_000.0);
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Error < Level::Off);
    }

    #[test]
    fn set_level_controls_enabled() {
        let prev = level();
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(prev);
    }

    #[test]
    fn macros_compile_and_run() {
        let prev = level();
        set_level(Level::Off);
        log_debug!("d {}", 1);
        log_info!("i");
        log_warn!("w");
        log_error!("e");
        set_level(prev);
    }
}
