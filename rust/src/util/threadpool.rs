//! Fixed-size named thread pools.
//!
//! TF-Serving §2.1.2 isolates *load* threads from *inference* threads so
//! a model being loaded can never steal cycles from requests in flight.
//! The managers in [`crate::lifecycle`] therefore own two `ThreadPool`s;
//! the RPC server and batch executor own their own.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    /// Signalled when the queue drains AND no job is running.
    idle: Condvar,
    running: AtomicUsize,
    shutdown: AtomicBool,
}

/// A fixed pool of worker threads consuming a FIFO job queue.
pub struct ThreadPool {
    name: String,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers named `<name>-<i>`.
    pub fn new(name: &str, threads: usize) -> Self {
        assert!(threads > 0, "thread pool needs at least one thread");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            idle: Condvar::new(),
            running: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                let tname = format!("{name}-{i}");
                std::thread::Builder::new()
                    .name(tname)
                    .spawn(move || Self::worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { name: name.to_string(), shared, workers }
    }

    fn worker_loop(shared: Arc<Shared>) {
        loop {
            let job = {
                let mut q = shared.queue.lock().unwrap();
                loop {
                    if let Some(job) = q.pop_front() {
                        break job;
                    }
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    q = shared.available.wait(q).unwrap();
                }
            };
            shared.running.fetch_add(1, Ordering::SeqCst);
            // Panics in jobs are isolated to the job, not the worker.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
            shared.running.fetch_sub(1, Ordering::SeqCst);
            // Wake joiners whether the job succeeded or panicked.
            {
                let _q = shared.queue.lock().unwrap();
                shared.idle.notify_all();
            }
            if result.is_err() {
                // Already reported by the panic hook; keep serving.
            }
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            assert!(
                !self.shared.shutdown.load(Ordering::SeqCst),
                "execute on shut-down pool {}",
                self.name
            );
            q.push_back(Box::new(f));
        }
        self.shared.available.notify_one();
    }

    /// Block until the queue is empty and all workers are idle.
    pub fn wait_idle(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        while !q.is_empty() || self.shared.running.load(Ordering::SeqCst) > 0 {
            q = self.shared.idle.wait(q).unwrap();
        }
    }

    /// Number of queued (not yet started) jobs.
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().len()
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.available.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Drain jobs that never ran (shutdown drops them).
    }
}

/// Completion counter for fan-out/fan-in over a pool.
///
/// ```no_run
/// # use tensorserve::util::threadpool::{ThreadPool, WaitGroup};
/// let pool = ThreadPool::new("w", 4);
/// let wg = WaitGroup::new();
/// for _ in 0..16 {
///     let t = wg.token();
///     pool.execute(move || { drop(t); });
/// }
/// wg.wait();
/// ```
pub struct WaitGroup {
    state: Arc<(Mutex<usize>, Condvar)>,
}

/// RAII token; dropping it signals completion of one task.
pub struct WaitToken {
    state: Arc<(Mutex<usize>, Condvar)>,
}

impl WaitGroup {
    pub fn new() -> Self {
        WaitGroup { state: Arc::new((Mutex::new(0), Condvar::new())) }
    }

    /// Register one outstanding task.
    pub fn token(&self) -> WaitToken {
        *self.state.0.lock().unwrap() += 1;
        WaitToken { state: Arc::clone(&self.state) }
    }

    /// Block until every token has been dropped.
    pub fn wait(&self) {
        let mut n = self.state.0.lock().unwrap();
        while *n > 0 {
            n = self.state.1.wait(n).unwrap();
        }
    }
}

impl Default for WaitGroup {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for WaitToken {
    fn drop(&mut self) {
        let mut n = self.state.0.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.state.1.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new("t", 4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_when_already_idle() {
        let pool = ThreadPool::new("t", 2);
        pool.wait_idle();
    }

    #[test]
    fn panicking_job_does_not_kill_worker() {
        let pool = ThreadPool::new("t", 1);
        pool.execute(|| panic!("boom"));
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            d.store(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new("t", 4);
        let wg = WaitGroup::new();
        let t0 = std::time::Instant::now();
        for _ in 0..4 {
            let t = wg.token();
            pool.execute(move || {
                std::thread::sleep(Duration::from_millis(50));
                drop(t);
            });
        }
        wg.wait();
        // 4 x 50ms on 4 threads should be well under 4*50ms.
        assert!(t0.elapsed() < Duration::from_millis(150));
    }

    #[test]
    fn waitgroup_counts() {
        let wg = WaitGroup::new();
        let t1 = wg.token();
        let t2 = wg.token();
        drop(t1);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        let state_done = std::thread::spawn(move || {
            d.store(1, Ordering::SeqCst);
        });
        drop(t2);
        wg.wait();
        state_done.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new("t", 2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
