//! OS-memory helpers backing two §2.1.2 optimizations:
//!
//! * *"Releasing memory to the operating system upon servable unload"* —
//!   [`release_to_os`] (glibc `malloc_trim`, declared directly so no
//!   `libc` crate is needed in the offline build).
//! * RSS probing so the transition-policy bench (experiment T4) and the
//!   TFS² Controller's RAM ledger can observe real memory.
//!
//! Plus the process-wide ledger of bytes parked in buffer pools
//! ([`pooled_buffer_bytes`]): pooled tensor storage shows up in RSS but
//! is instantly reusable, so capacity accounting and leak triage want
//! it broken out.

use std::sync::atomic::{AtomicI64, Ordering};

#[cfg(all(target_os = "linux", target_env = "gnu"))]
extern "C" {
    // glibc malloc.h; thread-safe (not async-signal-safe).
    fn malloc_trim(pad: usize) -> i32;
}

/// Ask the allocator to return free heap pages to the OS.
///
/// TF-Serving calls the platform allocator's trim after unloading a
/// servable so a multi-hundred-MB model's pages actually leave the
/// process. On glibc this is `malloc_trim(0)`; elsewhere it is a no-op.
pub fn release_to_os() -> bool {
    #[cfg(all(target_os = "linux", target_env = "gnu"))]
    {
        unsafe { malloc_trim(0) != 0 }
    }
    #[cfg(not(all(target_os = "linux", target_env = "gnu")))]
    {
        false
    }
}

/// Resident set size of this process in bytes (Linux), else 0.
pub fn current_rss_bytes() -> u64 {
    #[cfg(target_os = "linux")]
    {
        if let Ok(statm) = std::fs::read_to_string("/proc/self/statm") {
            if let Some(rss_pages) = statm.split_whitespace().nth(1) {
                if let Ok(pages) = rss_pages.parse::<u64>() {
                    return pages * page_size();
                }
            }
        }
        0
    }
    #[cfg(not(target_os = "linux"))]
    {
        0
    }
}

/// System page size in bytes via `sysconf(_SC_PAGESIZE)` (value 30 on
/// every Linux libc this repo targets), falling back to 4096.
#[cfg(target_os = "linux")]
fn page_size() -> u64 {
    extern "C" {
        // C `long` return: isize matches long's width on every Linux
        // target (ILP32 and LP64 alike).
        fn sysconf(name: i32) -> isize;
    }
    const SC_PAGESIZE: i32 = 30;
    let v = unsafe { sysconf(SC_PAGESIZE) };
    if v > 0 {
        v as u64
    } else {
        4096
    }
}

// ----------------------------------------------------- pool accounting

/// Bytes currently parked in [`crate::util::pool::BufferPool`] shelves,
/// process-wide. Signed internally so concurrent add/sub never wraps.
static POOL_BYTES: AtomicI64 = AtomicI64::new(0);

/// Called by buffer pools when they shelve (+) or hand out (-) storage.
pub fn note_pool_bytes(delta: i64) {
    POOL_BYTES.fetch_add(delta, Ordering::Relaxed);
}

/// Bytes of tensor storage currently held by buffer pools (counted in
/// RSS but free for reuse).
pub fn pooled_buffer_bytes() -> u64 {
    POOL_BYTES.load(Ordering::Relaxed).max(0) as u64
}

/// A deliberately large heap allocation standing in for model weights in
/// tests/benches that need realistic memory pressure without real HLO.
pub struct WeightBlob {
    data: Vec<u8>,
}

impl WeightBlob {
    /// Allocate and *touch* `bytes` (so RSS actually grows).
    pub fn new(bytes: usize) -> Self {
        let mut data = vec![0u8; bytes];
        // Touch one byte per page to fault the pages in.
        let page = 4096;
        for i in (0..data.len()).step_by(page) {
            data[i] = 1;
        }
        WeightBlob { data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Checksum touch — keeps the optimizer from eliding the blob.
    pub fn checksum(&self) -> u64 {
        self.data.iter().step_by(4096).map(|&b| b as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rss_is_nonzero_on_linux() {
        #[cfg(target_os = "linux")]
        assert!(current_rss_bytes() > 1024 * 1024);
    }

    #[test]
    fn release_to_os_runs() {
        // Just must not crash; return value is allocator-dependent.
        let _ = release_to_os();
    }

    #[test]
    fn weight_blob_touches_pages() {
        let blob = WeightBlob::new(1 << 20);
        assert_eq!(blob.len(), 1 << 20);
        assert!(blob.checksum() >= 256); // one touched byte per page
    }

    #[test]
    fn rss_grows_with_allocation() {
        #[cfg(target_os = "linux")]
        {
            let before = current_rss_bytes();
            let blob = WeightBlob::new(64 << 20);
            let during = current_rss_bytes();
            assert!(blob.checksum() > 0);
            assert!(
                during > before + (32 << 20),
                "rss before={before} during={during}"
            );
        }
    }
}
