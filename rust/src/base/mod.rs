//! Core servable abstractions (paper §2.1).
//!
//! A *servable* is the black box the library manages: usually an ML
//! model, but possibly a lookup table or anything else ("the mention of
//! BananaFlow"). Modules here define the identity type, the type-erased
//! box ("a safe `void*`-like construct"), reference-counted handles with
//! deferred destruction, the [`loader::Loader`] contract, and the
//! *aspired versions* API that connects Sources to Managers.

pub mod aspired;
pub mod error;
pub mod loader;
pub mod reclaim;
pub mod servable;
pub mod tensor;
