//! Dense tensors crossing the serving boundary — **views over shared
//! storage**.
//!
//! A [`Tensor`] is `(Arc<[f32]> storage, element offset, shape)`: a
//! row-major window into a reference-counted buffer. The representation
//! exists for the §2.1.2 promise that "the core code paths … have been
//! carefully optimized": the batch-dimension operations the serving hot
//! path leans on are metadata-only —
//!
//! * [`Tensor::split`] returns per-caller views of the merged output
//!   buffer (no copies; one `Arc` bump per part),
//! * [`Tensor::truncate_batch`] un-pads by shrinking the leading dim in
//!   place (no copy at all),
//! * [`Tensor::row`] is a slice into storage.
//!
//! Operations that genuinely materialize bytes — [`Tensor::concat`],
//! [`Tensor::pad_batch`], [`Tensor::build_with`] — write once into a
//! single exactly-sized allocation, optionally recycled through
//! [`crate::util::pool::BufferPool`]. The batching layer
//! ([`crate::batching::session`]) composes these into a
//! one-copy-per-request pipeline: request rows are written straight
//! into a pooled device buffer and results come back as views.
//!
//! Heavy math happens inside the AOT-compiled HLO, not here.

use crate::util::pool::BufferPool;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Row-major f32 tensor: a view over shared storage.
#[derive(Debug, Clone)]
pub struct Tensor {
    shape: Vec<usize>,
    storage: Arc<[f32]>,
    /// Element offset of this view's first element within `storage`.
    offset: usize,
}

/// Logical equality: shape and element contents (storage identity and
/// offsets are representation details).
impl PartialEq for Tensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape, storage: data.into(), offset: 0 })
    }

    /// View over an existing shared buffer: `shape.product()` elements
    /// starting at `offset`. General-purpose zero-copy constructor for
    /// callers that manage their own storage (the in-tree hot paths use
    /// [`Tensor::build_with`] plus `split`/`truncate_batch` views).
    pub fn from_shared(shape: Vec<usize>, storage: Arc<[f32]>, offset: usize) -> Result<Self> {
        let end = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .and_then(|n| offset.checked_add(n));
        match end {
            Some(end) if end <= storage.len() => Ok(Tensor { shape, storage, offset }),
            _ => bail!(
                "view at offset {offset} with shape {shape:?} exceeds storage of {} elements",
                storage.len()
            ),
        }
    }

    /// Allocate storage for `shape` (recycled from `pool` when
    /// possible) and fill it in place — one allocation, no intermediate
    /// `Vec`. The pool hands back a size-class buffer of at least
    /// `shape.product()` elements; `fill` sees (and the view exposes)
    /// exactly the first `shape.product()`.
    pub fn build_with(
        shape: Vec<usize>,
        pool: &BufferPool,
        fill: impl FnOnce(&mut [f32]),
    ) -> Self {
        let n: usize = shape.iter().product();
        let mut storage = pool.acquire(n);
        // The pool guarantees a uniquely-owned buffer.
        fill(&mut Arc::get_mut(&mut storage).expect("pool buffer uniquely owned")[..n]);
        Tensor { shape, storage, offset: 0 }
    }

    /// Fallible [`Tensor::build_with`]: when `fill` errors, the
    /// acquired buffer goes straight back to the pool and the error
    /// propagates — callers decoding untrusted input (the HTTP JSON
    /// codec) never have to remember the recycle-on-error step.
    pub fn try_build_with(
        shape: Vec<usize>,
        pool: &BufferPool,
        fill: impl FnOnce(&mut [f32]) -> Result<()>,
    ) -> Result<Self> {
        let n: usize = shape.iter().product();
        let mut storage = pool.acquire(n);
        match fill(&mut Arc::get_mut(&mut storage).expect("pool buffer uniquely owned")[..n]) {
            Ok(()) => Ok(Tensor { shape, storage, offset: 0 }),
            Err(e) => {
                pool.release(storage);
                Err(e)
            }
        }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape,
            storage: std::iter::repeat(0.0).take(n).collect(),
            offset: 0,
        }
    }

    /// 1-D tensor from a vec.
    pub fn vec(data: Vec<f32>) -> Self {
        Tensor { shape: vec![data.len()], storage: data.into(), offset: 0 }
    }

    /// 2-D tensor from rows.
    pub fn matrix(rows: Vec<Vec<f32>>) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|x| x.len() != c) {
            bail!("ragged rows");
        }
        let data: Vec<f32> = rows.into_iter().flatten().collect();
        Ok(Tensor { shape: vec![r, c], storage: data.into(), offset: 0 })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Number of elements in this view.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn data(&self) -> &[f32] {
        &self.storage[self.offset..self.offset + self.len()]
    }

    /// Copy the elements out. (Views cannot give the buffer away — the
    /// storage may be shared with sibling views.)
    pub fn into_data(self) -> Vec<f32> {
        self.data().to_vec()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Leading (batch) dimension, or 0 for rank-0.
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    /// Elements per batch row.
    pub fn row_elems(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// One batch row as a slice (O(1); no copy).
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_elems();
        &self.data()[i * w..(i + 1) * w]
    }

    /// True if both views window the same backing allocation (the
    /// zero-copy invariant checked by tests).
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        Arc::ptr_eq(&self.storage, &other.storage)
    }

    /// The shared backing buffer (offset 0 of the whole allocation).
    pub fn storage(&self) -> &Arc<[f32]> {
        &self.storage
    }

    /// Recycle this tensor's backing buffer into `pool` if this view
    /// starts at the allocation's origin. The pool itself declines
    /// buffers that are still shared (live sibling views) or not
    /// class-sized, so this is always safe; a declined buffer just
    /// drops normally.
    pub fn recycle_into(self, pool: &BufferPool) {
        if self.offset == 0 {
            pool.release(self.storage);
        }
    }

    /// Batching-compatibility check shared by [`Tensor::concat`] and
    /// the fused assembly in [`crate::batching::session`]: every part
    /// must have rank >= 1 and identical trailing dims. Returns the
    /// summed batch rows and the trailing dims.
    pub fn concat_shape(parts: &[Tensor]) -> Result<(usize, Vec<usize>)> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        if first.rank() == 0 {
            bail!("concat shape mismatch: rank-0 tensor {:?}", first.shape);
        }
        let trailing = &first.shape[1..];
        let mut batch = 0usize;
        for p in parts {
            if p.rank() == 0 || &p.shape[1..] != trailing {
                bail!(
                    "concat shape mismatch: {:?} vs {:?}",
                    p.shape,
                    first.shape
                );
            }
            batch += p.shape[0];
        }
        Ok((batch, trailing.to_vec()))
    }

    /// Concatenate along dim 0. All inputs must agree on trailing dims.
    /// One exactly-sized allocation; one copy of each input.
    pub fn concat(parts: &[Tensor]) -> Result<Tensor> {
        let (batch, trailing) = Self::concat_shape(parts)?;
        let mut shape = vec![batch];
        shape.extend_from_slice(&trailing);
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(p.data());
        }
        Ok(Tensor { shape, storage: data.into(), offset: 0 })
    }

    /// Split along dim 0 into chunks of the given batch sizes.
    ///
    /// Zero-copy: every part is a view sharing this tensor's storage.
    pub fn split(&self, sizes: &[usize]) -> Result<Vec<Tensor>> {
        let total: usize = sizes.iter().sum();
        if total != self.batch() {
            bail!("split sizes {sizes:?} sum {total} != batch {}", self.batch());
        }
        let w = self.row_elems();
        let mut out = Vec::with_capacity(sizes.len());
        let mut off = 0usize;
        for &s in sizes {
            let mut shape = self.shape.clone();
            shape[0] = s;
            out.push(Tensor {
                shape,
                storage: Arc::clone(&self.storage),
                offset: self.offset + off * w,
            });
            off += s;
        }
        Ok(out)
    }

    /// Zero-pad the batch dimension up to `target` rows. Allocates (via
    /// the global buffer pool) — padding must materialize new bytes.
    pub fn pad_batch(&self, target: usize) -> Result<Tensor> {
        if target < self.batch() {
            bail!("pad target {target} < batch {}", self.batch());
        }
        let mut shape = self.shape.clone();
        shape[0] = target;
        let src = self.data();
        Ok(Tensor::build_with(shape, &BufferPool::global(), |buf| {
            buf[..src.len()].copy_from_slice(src);
            buf[src.len()..].fill(0.0);
        }))
    }

    /// Take the first `n` batch rows (inverse of `pad_batch`).
    ///
    /// Zero-copy: returns a view sharing this tensor's storage.
    pub fn truncate_batch(&self, n: usize) -> Result<Tensor> {
        if n > self.batch() {
            bail!("truncate {n} > batch {}", self.batch());
        }
        let mut shape = self.shape.clone();
        shape[0] = n;
        Ok(Tensor {
            shape,
            storage: Arc::clone(&self.storage),
            offset: self.offset,
        })
    }
}

/// Row-major i32 tensor (classifier class outputs) — same view
/// representation as [`Tensor`].
#[derive(Debug, Clone)]
pub struct TensorI32 {
    shape: Vec<usize>,
    storage: Arc<[i32]>,
    offset: usize,
}

impl PartialEq for TensorI32 {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(TensorI32 { shape, storage: data.into(), offset: 0 })
    }

    /// Allocate storage for `shape` (recycled from `pool` when
    /// possible) and fill it in place — the i32 mirror of
    /// [`Tensor::build_with`], so classifier class outputs go through
    /// the pool like f32 tensors.
    pub fn build_with(
        shape: Vec<usize>,
        pool: &BufferPool<i32>,
        fill: impl FnOnce(&mut [i32]),
    ) -> Self {
        let n: usize = shape.iter().product();
        let mut storage = pool.acquire(n);
        // The pool guarantees a uniquely-owned buffer.
        fill(&mut Arc::get_mut(&mut storage).expect("pool buffer uniquely owned")[..n]);
        TensorI32 { shape, storage, offset: 0 }
    }

    /// Recycle this tensor's backing buffer into `pool` if this view
    /// starts at the allocation's origin (mirror of
    /// [`Tensor::recycle_into`]; the pool declines shared or
    /// non-class-sized buffers).
    pub fn recycle_into(self, pool: &BufferPool<i32>) {
        if self.offset == 0 {
            pool.release(self.storage);
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn data(&self) -> &[i32] {
        &self.storage[self.offset..self.offset + self.len()]
    }

    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    pub fn shares_storage(&self, other: &TensorI32) -> bool {
        Arc::ptr_eq(&self.storage, &other.storage)
    }

    /// Zero-copy view of the first `n` batch rows.
    pub fn truncate_batch(&self, n: usize) -> Result<TensorI32> {
        if n > self.batch() {
            bail!("truncate {n} > batch {}", self.batch());
        }
        let mut shape = self.shape.clone();
        shape[0] = n;
        Ok(TensorI32 {
            shape,
            storage: Arc::clone(&self.storage),
            offset: self.offset,
        })
    }

    /// Concatenate along dim 0 (mirrors [`Tensor::concat`]). One
    /// exactly-sized allocation; one copy of each input.
    pub fn concat(parts: &[TensorI32]) -> Result<TensorI32> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        if first.shape.is_empty() {
            bail!("concat shape mismatch: rank-0 tensor {:?}", first.shape);
        }
        let trailing = &first.shape[1..];
        let mut batch = 0usize;
        for p in parts {
            if p.shape.is_empty() || &p.shape[1..] != trailing {
                bail!("concat shape mismatch: {:?} vs {:?}", p.shape, first.shape);
            }
            batch += p.shape[0];
        }
        let mut shape = vec![batch];
        shape.extend_from_slice(trailing);
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(p.data());
        }
        Ok(TensorI32 { shape, storage: data.into(), offset: 0 })
    }

    /// Zero-copy split along dim 0 (mirrors [`Tensor::split`]).
    pub fn split(&self, sizes: &[usize]) -> Result<Vec<TensorI32>> {
        let total: usize = sizes.iter().sum();
        if total != self.batch() {
            bail!("split sizes {sizes:?} sum {total} != batch {}", self.batch());
        }
        let w: usize = self.shape.iter().skip(1).product();
        let mut out = Vec::with_capacity(sizes.len());
        let mut off = 0usize;
        for &s in sizes {
            let mut shape = self.shape.clone();
            shape[0] = s;
            out.push(TensorI32 {
                shape,
                storage: Arc::clone(&self.storage),
                offset: self.offset + off * w,
            });
            off += s;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::matrix(vec![vec![1.0], vec![2.0, 3.0]]).is_err());
    }

    #[test]
    fn concat_and_split_roundtrip() {
        let a = Tensor::matrix(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Tensor::matrix(vec![vec![5.0, 6.0]]).unwrap();
        let c = Tensor::concat(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let parts = c.split(&[2, 1]).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn concat_rejects_mismatched_trailing() {
        let a = Tensor::zeros(vec![1, 2]);
        let b = Tensor::zeros(vec![1, 3]);
        assert!(Tensor::concat(&[a, b]).is_err());
    }

    #[test]
    fn split_validates_sizes() {
        let t = Tensor::zeros(vec![3, 2]);
        assert!(t.split(&[1, 1]).is_err());
        assert!(t.split(&[2, 1]).is_ok());
    }

    #[test]
    fn pad_and_truncate() {
        let t = Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap();
        let p = t.pad_batch(4).unwrap();
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(p.row(0), &[1.0, 2.0]);
        assert_eq!(p.row(3), &[0.0, 0.0]);
        let back = p.truncate_batch(1).unwrap();
        assert_eq!(back, t);
        assert!(t.pad_batch(0).is_err());
        assert!(t.truncate_batch(2).is_err());
    }

    #[test]
    fn rows_and_elems() {
        let t = Tensor::zeros(vec![4, 3, 2]);
        assert_eq!(t.batch(), 4);
        assert_eq!(t.row_elems(), 6);
        assert_eq!(t.row(2).len(), 6);
    }

    #[test]
    fn i32_tensor() {
        let t = TensorI32::new(vec![3], vec![1, 2, 3]).unwrap();
        assert_eq!(t.batch(), 3);
        assert_eq!(t.truncate_batch(2).unwrap().data(), &[1, 2]);
        assert!(TensorI32::new(vec![2], vec![1]).is_err());
    }

    // ---------------------------------------- zero-copy invariants

    #[test]
    fn split_returns_views_sharing_storage() {
        let t = Tensor::matrix(vec![
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ])
        .unwrap();
        let parts = t.split(&[1, 2]).unwrap();
        for p in &parts {
            assert!(p.shares_storage(&t), "split part copied its data");
        }
        // Pointer-level check: each part's slice aims into the parent.
        let base = t.data().as_ptr() as usize;
        assert_eq!(parts[0].data().as_ptr() as usize, base);
        assert_eq!(
            parts[1].data().as_ptr() as usize,
            base + 2 * std::mem::size_of::<f32>()
        );
        assert_eq!(parts[1].data(), &[3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn truncate_batch_is_a_view() {
        let t = Tensor::zeros(vec![8, 4]);
        let v = t.truncate_batch(3).unwrap();
        assert!(v.shares_storage(&t));
        assert_eq!(v.data().as_ptr(), t.data().as_ptr());
        assert_eq!(v.shape(), &[3, 4]);
    }

    #[test]
    fn nested_views_compose() {
        let t = Tensor::matrix((0..6).map(|i| vec![i as f32]).collect()).unwrap();
        let padded_view = t.truncate_batch(5).unwrap();
        let parts = padded_view.split(&[2, 3]).unwrap();
        assert!(parts[1].shares_storage(&t));
        assert_eq!(parts[1].data(), &[2.0, 3.0, 4.0]);
        // Views outlive the tensor they were split from.
        drop(t);
        drop(padded_view);
        assert_eq!(parts[0].data(), &[0.0, 1.0]);
    }

    #[test]
    fn i32_concat_roundtrip() {
        let a = TensorI32::new(vec![2, 2], vec![0, 1, 2, 3]).unwrap();
        let b = TensorI32::new(vec![1, 2], vec![4, 5]).unwrap();
        let c = TensorI32::concat(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[0, 1, 2, 3, 4, 5]);
        let parts = c.split(&[2, 1]).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
        // Mismatched trailing dims rejected, like f32.
        assert!(TensorI32::concat(&[
            TensorI32::new(vec![1, 2], vec![0, 1]).unwrap(),
            TensorI32::new(vec![1, 3], vec![0, 1, 2]).unwrap(),
        ])
        .is_err());
        assert!(TensorI32::concat(&[]).is_err());
    }

    #[test]
    fn i32_truncate_and_split_are_views() {
        let t = TensorI32::new(vec![4, 2], vec![0, 1, 2, 3, 4, 5, 6, 7]).unwrap();
        let v = t.truncate_batch(2).unwrap();
        assert!(v.shares_storage(&t));
        assert_eq!(v.data(), &[0, 1, 2, 3]);
        let parts = t.split(&[1, 3]).unwrap();
        assert!(parts[0].shares_storage(&t));
        assert_eq!(parts[1].data(), &[2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn from_shared_validates_bounds() {
        let storage: Arc<[f32]> = vec![0.0; 8].into();
        assert!(Tensor::from_shared(vec![2, 2], Arc::clone(&storage), 4).is_ok());
        assert!(Tensor::from_shared(vec![2, 2], Arc::clone(&storage), 5).is_err());
        assert!(Tensor::from_shared(vec![3, 3], storage, 0).is_err());
    }

    #[test]
    fn build_with_fills_in_place() {
        let pool = BufferPool::new(8, 1 << 20);
        let t = Tensor::build_with(vec![2, 3], &pool, |buf| {
            for (i, x) in buf.iter_mut().enumerate() {
                *x = i as f32;
            }
        });
        assert_eq!(t.data(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        // Recycle → next build of the same size reuses the allocation.
        let ptr = t.data().as_ptr();
        t.recycle_into(&pool);
        let t2 = Tensor::build_with(vec![6], &pool, |buf| buf.fill(9.0));
        assert_eq!(t2.data().as_ptr(), ptr, "pool did not recycle");
        assert_eq!(t2.data(), &[9.0; 6]);
    }

    #[test]
    fn try_build_with_recycles_on_error() {
        let pool = BufferPool::new(8, 1 << 20);
        let t = Tensor::try_build_with(vec![4], &pool, |buf| {
            buf.fill(2.0);
            Ok(())
        })
        .unwrap();
        assert_eq!(t.data(), &[2.0; 4]);
        t.recycle_into(&pool);
        let shelved = pool.stats().buffers_pooled;
        // A failing fill hands the buffer back to the pool itself.
        let err = Tensor::try_build_with(vec![4], &pool, |_| {
            anyhow::bail!("bad input")
        })
        .unwrap_err();
        assert!(err.to_string().contains("bad input"));
        assert_eq!(pool.stats().buffers_pooled, shelved);
    }

    #[test]
    fn i32_build_with_recycles_through_pool() {
        let pool: BufferPool<i32> = BufferPool::new(8, 1 << 20);
        let t = TensorI32::build_with(vec![3], &pool, |buf| {
            for (i, x) in buf.iter_mut().enumerate() {
                *x = i as i32;
            }
        });
        assert_eq!(t.data(), &[0, 1, 2]);
        let ptr = t.data().as_ptr();
        t.recycle_into(&pool);
        let t2 = TensorI32::build_with(vec![4], &pool, |buf| buf.fill(7));
        assert_eq!(t2.data().as_ptr(), ptr, "i32 pool did not recycle");
        assert_eq!(t2.data(), &[7; 4]);
        // Shared storage is declined, same as f32.
        let view = t2.truncate_batch(2).unwrap();
        t2.recycle_into(&pool);
        assert_eq!(view.data(), &[7, 7]);
        assert_eq!(pool.stats().buffers_pooled, 0);
    }

    #[test]
    fn recycle_declines_shared_storage() {
        let pool = BufferPool::new(8, 1 << 20);
        let t = Tensor::build_with(vec![4], &pool, |b| b.fill(1.0));
        let view = t.truncate_batch(2).unwrap();
        // Two owners: recycling must not shelve the buffer while the
        // sibling view is alive.
        t.recycle_into(&pool);
        assert_eq!(view.data(), &[1.0, 1.0]);
        assert_eq!(pool.stats().buffers_pooled, 0);
    }
}
