//! Dense tensors crossing the serving boundary.
//!
//! Deliberately minimal: f32/i32 row-major tensors with the operations
//! the serving path needs — batch-dimension concat/split (the essence of
//! inter-request batching, §2.2.1) and zero-padding to an allowed batch
//! size. Heavy math happens inside the AOT-compiled HLO, not here.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// 1-D tensor from a vec.
    pub fn vec(data: Vec<f32>) -> Self {
        Tensor { shape: vec![data.len()], data }
    }

    /// 2-D tensor from rows.
    pub fn matrix(rows: Vec<Vec<f32>>) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|x| x.len() != c) {
            bail!("ragged rows");
        }
        Ok(Tensor { shape: vec![r, c], data: rows.into_iter().flatten().collect() })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Leading (batch) dimension, or 0 for rank-0.
    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    /// Elements per batch row.
    pub fn row_elems(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    /// One batch row as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_elems();
        &self.data[i * w..(i + 1) * w]
    }

    /// Concatenate along dim 0. All inputs must agree on trailing dims.
    pub fn concat(parts: &[Tensor]) -> Result<Tensor> {
        let first = parts.first().ok_or_else(|| anyhow::anyhow!("empty concat"))?;
        let trailing = &first.shape[1..];
        let mut batch = 0usize;
        let mut data = Vec::new();
        for p in parts {
            if p.rank() == 0 || &p.shape[1..] != trailing {
                bail!(
                    "concat shape mismatch: {:?} vs {:?}",
                    p.shape,
                    first.shape
                );
            }
            batch += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![batch];
        shape.extend_from_slice(trailing);
        Ok(Tensor { shape, data })
    }

    /// Split along dim 0 into chunks of the given batch sizes.
    pub fn split(&self, sizes: &[usize]) -> Result<Vec<Tensor>> {
        let total: usize = sizes.iter().sum();
        if total != self.batch() {
            bail!("split sizes {sizes:?} sum {total} != batch {}", self.batch());
        }
        let w = self.row_elems();
        let mut out = Vec::with_capacity(sizes.len());
        let mut off = 0usize;
        for &s in sizes {
            let mut shape = self.shape.clone();
            shape[0] = s;
            out.push(Tensor {
                shape,
                data: self.data[off * w..(off + s) * w].to_vec(),
            });
            off += s;
        }
        Ok(out)
    }

    /// Zero-pad the batch dimension up to `target` rows.
    pub fn pad_batch(&self, target: usize) -> Result<Tensor> {
        if target < self.batch() {
            bail!("pad target {target} < batch {}", self.batch());
        }
        let mut shape = self.shape.clone();
        shape[0] = target;
        let mut data = self.data.clone();
        data.resize(target * self.row_elems(), 0.0);
        Ok(Tensor { shape, data })
    }

    /// Take the first `n` batch rows (inverse of `pad_batch`).
    pub fn truncate_batch(&self, n: usize) -> Result<Tensor> {
        if n > self.batch() {
            bail!("truncate {n} > batch {}", self.batch());
        }
        let mut shape = self.shape.clone();
        shape[0] = n;
        Ok(Tensor { shape, data: self.data[..n * self.row_elems()].to_vec() })
    }
}

/// Row-major i32 tensor (classifier class outputs).
#[derive(Debug, Clone, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(TensorI32 { shape, data })
    }

    pub fn batch(&self) -> usize {
        self.shape.first().copied().unwrap_or(0)
    }

    pub fn truncate_batch(&self, n: usize) -> Result<TensorI32> {
        let w: usize = self.shape.iter().skip(1).product();
        if n > self.batch() {
            bail!("truncate {n} > batch {}", self.batch());
        }
        let mut shape = self.shape.clone();
        shape[0] = n;
        Ok(TensorI32 { shape, data: self.data[..n * w].to_vec() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::matrix(vec![vec![1.0], vec![2.0, 3.0]]).is_err());
    }

    #[test]
    fn concat_and_split_roundtrip() {
        let a = Tensor::matrix(vec![vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Tensor::matrix(vec![vec![5.0, 6.0]]).unwrap();
        let c = Tensor::concat(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let parts = c.split(&[2, 1]).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn concat_rejects_mismatched_trailing() {
        let a = Tensor::zeros(vec![1, 2]);
        let b = Tensor::zeros(vec![1, 3]);
        assert!(Tensor::concat(&[a, b]).is_err());
    }

    #[test]
    fn split_validates_sizes() {
        let t = Tensor::zeros(vec![3, 2]);
        assert!(t.split(&[1, 1]).is_err());
        assert!(t.split(&[2, 1]).is_ok());
    }

    #[test]
    fn pad_and_truncate() {
        let t = Tensor::matrix(vec![vec![1.0, 2.0]]).unwrap();
        let p = t.pad_batch(4).unwrap();
        assert_eq!(p.shape(), &[4, 2]);
        assert_eq!(p.row(0), &[1.0, 2.0]);
        assert_eq!(p.row(3), &[0.0, 0.0]);
        let back = p.truncate_batch(1).unwrap();
        assert_eq!(back, t);
        assert!(t.pad_batch(0).is_err());
        assert!(t.truncate_batch(2).is_err());
    }

    #[test]
    fn rows_and_elems() {
        let t = Tensor::zeros(vec![4, 3, 2]);
        assert_eq!(t.batch(), 4);
        assert_eq!(t.row_elems(), 6);
        assert_eq!(t.row(2).len(), 6);
    }

    #[test]
    fn i32_tensor() {
        let t = TensorI32::new(vec![3], vec![1, 2, 3]).unwrap();
        assert_eq!(t.batch(), 3);
        assert_eq!(t.truncate_batch(2).unwrap().data, vec![1, 2]);
        assert!(TensorI32::new(vec![2], vec![1]).is_err());
    }
}
