//! Typed error kinds carried through the serving stack.
//!
//! The core's error path is `anyhow`, which is great for messages and
//! terrible for dispatch: the HTTP gateway used to decide 404-vs-400 by
//! substring-matching error text. [`ErrorKind`] is a small, wire-stable
//! classification attached at the *site that knows* (lookup failures
//! are `NotFound`, validation failures are `InvalidArgument`,
//! lifecycle races are `FailedPrecondition`) and recovered anywhere
//! downstream with [`ErrorKind::of`] — including on the far side of an
//! RPC, since `Response::Error` carries the kind on the wire.
//!
//! Errors created without a kind classify as [`ErrorKind::Internal`]
//! — a server fault unless a consumer's own heuristic (the gateway's
//! lookup-substring rescue) says otherwise.

use std::fmt;

/// The coarse classification of a serving error — what a client should
/// *do* about it, not what went wrong.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ErrorKind {
    /// The addressed thing (model, version, label, output) does not
    /// exist. Clients should not retry unchanged.
    NotFound,
    /// The request itself is malformed (bad shape, unknown signature,
    /// conflicting spec). Clients should not retry unchanged.
    InvalidArgument,
    /// The request was valid but the system's state made it
    /// unservable (version unloading mid-flight, queue shedding
    /// load). Clients may retry.
    FailedPrecondition,
    /// The request's deadline expired before execution. Retrying with
    /// the same deadline will likely expire again; retry with a larger
    /// budget or shed the work.
    DeadlineExceeded,
    /// The server is shedding load (admission limits hit, drain in
    /// progress). Transient by construction: clients should retry
    /// after backing off.
    Unavailable,
    /// Everything else, including errors that never got a kind.
    Internal,
}

impl ErrorKind {
    /// Wrap a message in an `anyhow::Error` carrying this kind.
    /// `e.to_string()` is exactly `message` — attaching a kind never
    /// changes what callers (and their pinned tests) see.
    pub fn err(self, message: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(KindedError { kind: self, message: message.into() })
    }

    /// Recover the kind from an error; `Internal` when none was
    /// attached.
    pub fn of(err: &anyhow::Error) -> ErrorKind {
        err.downcast_ref::<KindedError>()
            .map(|k| k.kind)
            .unwrap_or(ErrorKind::Internal)
    }

    /// Stable wire code (see `rpc::proto`'s `Response::Error`).
    pub fn code(self) -> u8 {
        match self {
            ErrorKind::NotFound => 1,
            ErrorKind::InvalidArgument => 2,
            ErrorKind::FailedPrecondition => 3,
            ErrorKind::DeadlineExceeded => 4,
            ErrorKind::Unavailable => 5,
            ErrorKind::Internal => 0,
        }
    }

    /// Inverse of [`ErrorKind::code`]. Unknown codes from newer peers
    /// degrade to `Internal` rather than failing the whole frame.
    pub fn from_code(code: u8) -> ErrorKind {
        match code {
            1 => ErrorKind::NotFound,
            2 => ErrorKind::InvalidArgument,
            3 => ErrorKind::FailedPrecondition,
            4 => ErrorKind::DeadlineExceeded,
            5 => ErrorKind::Unavailable,
            _ => ErrorKind::Internal,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ErrorKind::NotFound => "NOT_FOUND",
            ErrorKind::InvalidArgument => "INVALID_ARGUMENT",
            ErrorKind::FailedPrecondition => "FAILED_PRECONDITION",
            ErrorKind::DeadlineExceeded => "DEADLINE_EXCEEDED",
            ErrorKind::Unavailable => "UNAVAILABLE",
            ErrorKind::Internal => "INTERNAL",
        }
    }

    /// Whether a client may retry the identical request and reasonably
    /// expect success: the condition is transient server state, not a
    /// property of the request. `FailedPrecondition` covers the unload
    /// drain ("version unloading — retry"), `Unavailable` covers load
    /// shedding. `DeadlineExceeded` is deliberately NOT retryable: the
    /// same budget will expire the same way.
    pub fn is_retryable(self) -> bool {
        matches!(self, ErrorKind::FailedPrecondition | ErrorKind::Unavailable)
    }
}

impl fmt::Display for ErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The concrete error type [`ErrorKind::err`] builds: displays as the
/// bare message so kinds are invisible to message-oriented callers.
#[derive(Debug)]
struct KindedError {
    kind: ErrorKind,
    message: String,
}

impl fmt::Display for KindedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for KindedError {}

/// `bail!` with a kind: `bail_kind!(ErrorKind::NotFound, "no {thing}")`.
#[macro_export]
macro_rules! bail_kind {
    ($kind:expr, $($arg:tt)*) => {
        return Err($kind.err(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::anyhow;

    #[test]
    fn kind_roundtrips_through_anyhow() {
        let e = ErrorKind::NotFound.err("servable 'x' not found");
        assert_eq!(e.to_string(), "servable 'x' not found");
        assert_eq!(ErrorKind::of(&e), ErrorKind::NotFound);
        // Plain errors classify as Internal.
        assert_eq!(ErrorKind::of(&anyhow!("boom")), ErrorKind::Internal);
    }

    #[test]
    fn kind_survives_context_layers() {
        use anyhow::Context;
        let e = ErrorKind::FailedPrecondition
            .err("version unloading")
            .context("while serving request");
        assert_eq!(ErrorKind::of(&e), ErrorKind::FailedPrecondition);
    }

    #[test]
    fn wire_codes_roundtrip() {
        for kind in [
            ErrorKind::NotFound,
            ErrorKind::InvalidArgument,
            ErrorKind::FailedPrecondition,
            ErrorKind::DeadlineExceeded,
            ErrorKind::Unavailable,
            ErrorKind::Internal,
        ] {
            assert_eq!(ErrorKind::from_code(kind.code()), kind);
        }
        // Unknown codes degrade, not fail.
        assert_eq!(ErrorKind::from_code(99), ErrorKind::Internal);
    }

    #[test]
    fn retryable_kinds() {
        assert!(ErrorKind::FailedPrecondition.is_retryable());
        assert!(ErrorKind::Unavailable.is_retryable());
        assert!(!ErrorKind::DeadlineExceeded.is_retryable());
        assert!(!ErrorKind::NotFound.is_retryable());
        assert!(!ErrorKind::InvalidArgument.is_retryable());
        assert!(!ErrorKind::Internal.is_retryable());
    }

    #[test]
    fn bail_kind_macro() {
        fn lookup(ok: bool) -> anyhow::Result<u32> {
            if !ok {
                bail_kind!(ErrorKind::NotFound, "model '{}' not found", "m");
            }
            Ok(7)
        }
        assert_eq!(lookup(true).unwrap(), 7);
        let e = lookup(false).unwrap_err();
        assert_eq!(ErrorKind::of(&e), ErrorKind::NotFound);
        assert_eq!(e.to_string(), "model 'm' not found");
    }
}
