//! The [`Loader`] contract: how a servable gets into and out of memory.
//!
//! Source Adapters emit `Arc<dyn Loader>` per (servable, version); the
//! Manager sequences calls to `load`/`unload` (§2.1). `estimate` is
//! consulted *before* load for admission control and by the TFS²
//! Controller's bin-packing.

use super::servable::ServableBox;
use anyhow::Result;

/// Resources a servable (version) needs while memory-resident.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceEstimate {
    pub ram_bytes: u64,
}

impl ResourceEstimate {
    pub fn ram(ram_bytes: u64) -> Self {
        ResourceEstimate { ram_bytes }
    }
}

/// Loads one version of one servable.
///
/// Implementations must be safe to call from a dedicated *load* thread
/// pool while inference proceeds on other versions (§2.1.2 isolation).
pub trait Loader: Send + Sync {
    /// Resource needs, available *before* loading (used for admission
    /// control and bin-packing). Estimates should be conservative.
    fn estimate(&self) -> Result<ResourceEstimate>;

    /// Materialize the servable in memory. Called at most once per
    /// harness attempt; may be retried on failure with a fresh call.
    fn load(&self) -> Result<ServableBox>;

    /// Hook invoked with the servable just before its memory is
    /// reclaimed. Default: nothing (dropping the box is the unload).
    fn unload(&self, _servable: &ServableBox) {}

    /// Debug name for logs.
    fn describe(&self) -> String {
        "loader".to_string()
    }
}

/// A [`Loader`] built from closures — the unit-test workhorse and the
/// basis for simple servables (tables, constants).
pub struct FnLoader {
    estimate: ResourceEstimate,
    load_fn: Box<dyn Fn() -> Result<ServableBox> + Send + Sync>,
    describe: String,
}

impl FnLoader {
    pub fn new<F>(estimate: ResourceEstimate, describe: &str, load_fn: F) -> Self
    where
        F: Fn() -> Result<ServableBox> + Send + Sync + 'static,
    {
        FnLoader { estimate, load_fn: Box::new(load_fn), describe: describe.to_string() }
    }

    /// Loader that yields a fixed value.
    pub fn constant<T: Clone + Send + Sync + 'static>(value: T) -> Self {
        FnLoader::new(ResourceEstimate::default(), "constant", move || {
            Ok(std::sync::Arc::new(value.clone()) as ServableBox)
        })
    }

    /// Loader that always fails (for error-path tests).
    pub fn failing(msg: &str) -> Self {
        let msg = msg.to_string();
        FnLoader::new(ResourceEstimate::default(), "failing", move || {
            Err(anyhow::anyhow!("{msg}"))
        })
    }
}

impl Loader for FnLoader {
    fn estimate(&self) -> Result<ResourceEstimate> {
        Ok(self.estimate)
    }

    fn load(&self) -> Result<ServableBox> {
        (self.load_fn)()
    }

    fn describe(&self) -> String {
        self.describe.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_loader_roundtrip() {
        let l = FnLoader::constant(7u32);
        let s = l.load().unwrap();
        assert_eq!(*s.downcast::<u32>().unwrap(), 7);
        assert_eq!(l.estimate().unwrap().ram_bytes, 0);
    }

    #[test]
    fn failing_loader_errors() {
        let l = FnLoader::failing("nope");
        assert!(l.load().unwrap_err().to_string().contains("nope"));
    }

    #[test]
    fn estimate_is_preload() {
        let l = FnLoader::new(ResourceEstimate::ram(1024), "big", || {
            Ok(std::sync::Arc::new(0u8) as ServableBox)
        });
        // estimate works without load
        assert_eq!(l.estimate().unwrap().ram_bytes, 1024);
        assert_eq!(l.describe(), "big");
    }
}
