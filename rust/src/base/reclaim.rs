//! Deferred destruction: a dedicated thread that drops what it is sent.
//!
//! Freeing a large model is slow (page-table churn, allocator work, and
//! — per §2.1.2 — `malloc_trim` to hand pages back to the OS). Handles
//! and managers ship their final `Arc` references here so that work
//! never rides an inference thread.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};

enum Msg {
    /// Drop this on the reclaim thread.
    Reclaim(Box<dyn Send>),
    /// Drop this, then trim the allocator (used on servable unload).
    ReclaimAndTrim(Box<dyn Send>),
    /// Reply when everything enqueued before this has been dropped.
    Flush(Sender<()>),
}

struct Inner {
    tx: Mutex<Option<Sender<Msg>>>,
    joined: Mutex<Option<std::thread::JoinHandle<()>>>,
    _cv: Condvar,
}

/// Handle to the reclaim thread. Cheap to clone; the thread stops when
/// the last clone drops.
#[derive(Clone)]
pub struct Reclaimer {
    inner: Arc<Inner>,
}

impl Reclaimer {
    /// Start a reclaim thread named `<name>-reclaim`.
    pub fn start(name: &str) -> Self {
        let (tx, rx) = channel::<Msg>();
        let handle = std::thread::Builder::new()
            .name(format!("{name}-reclaim"))
            .spawn(move || {
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Reclaim(b) => drop(b),
                        Msg::ReclaimAndTrim(b) => {
                            drop(b);
                            // Pooled tensor buffers would keep trimmed
                            // pages resident: empty the shelves (both
                            // element types) first so malloc_trim can
                            // hand them back.
                            crate::util::pool::BufferPool::global().clear();
                            crate::util::pool::BufferPool::global_i32().clear();
                            crate::util::mem::release_to_os();
                        }
                        Msg::Flush(reply) => {
                            let _ = reply.send(());
                        }
                    }
                }
            })
            .expect("spawn reclaim thread");
        Reclaimer {
            inner: Arc::new(Inner {
                tx: Mutex::new(Some(tx)),
                joined: Mutex::new(Some(handle)),
                _cv: Condvar::new(),
            }),
        }
    }

    #[cfg(test)]
    pub fn start_for_test() -> Self {
        Self::start("test")
    }

    /// Defer dropping `value` to the reclaim thread.
    pub fn defer<T: Send + 'static>(&self, value: T) {
        self.send(Msg::Reclaim(Box::new(value)));
    }

    /// Defer dropping `value`, then release freed pages to the OS
    /// (§2.1.2 "Releasing memory to the operating system upon servable
    /// unload").
    pub fn defer_and_trim<T: Send + 'static>(&self, value: T) {
        self.send(Msg::ReclaimAndTrim(Box::new(value)));
    }

    fn send(&self, msg: Msg) {
        let tx = self.inner.tx.lock().unwrap();
        if let Some(tx) = tx.as_ref() {
            // If the thread is gone (process teardown) drop inline.
            let _ = tx.send(msg);
        }
    }

    /// Block until everything deferred so far has been dropped.
    pub fn flush(&self) {
        let (reply_tx, reply_rx) = channel();
        self.send(Msg::Flush(reply_tx));
        let _ = reply_rx.recv();
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        // Close the channel, then join so deferred drops finish.
        *self.tx.lock().unwrap() = None;
        if let Some(h) = self.joined.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static DROPS: AtomicUsize = AtomicUsize::new(0);

    struct Counted;
    impl Drop for Counted {
        fn drop(&mut self) {
            DROPS.fetch_add(1, Ordering::SeqCst);
        }
    }

    #[test]
    fn defers_and_flushes() {
        let r = Reclaimer::start("t1");
        let before = DROPS.load(Ordering::SeqCst);
        for _ in 0..10 {
            r.defer(Counted);
        }
        r.flush();
        assert_eq!(DROPS.load(Ordering::SeqCst), before + 10);
    }

    #[test]
    fn defer_and_trim_works() {
        let r = Reclaimer::start("t2");
        r.defer_and_trim(vec![0u8; 1 << 20]);
        r.flush();
    }

    #[test]
    fn drop_joins_and_drains() {
        let before = DROPS.load(Ordering::SeqCst);
        {
            let r = Reclaimer::start("t3");
            for _ in 0..5 {
                r.defer(Counted);
            }
        } // drop joins the thread
        assert_eq!(DROPS.load(Ordering::SeqCst), before + 5);
    }

    #[test]
    fn clones_share_thread() {
        let r = Reclaimer::start("t4");
        let r2 = r.clone();
        r.defer(Counted);
        r2.flush();
    }
}
