//! The *aspired versions* API (§2.1) — the uni-directional, idempotent
//! contract connecting Sources (via Routers and Adapters) to Managers.
//!
//! A call names a servable and the full list of versions the caller
//! would like memory-resident; versions omitted are implicitly
//! *un*-aspired. Idempotence lets a Source re-emit its full state on
//! every poll without knowing what is currently loaded.

use super::servable::ServableId;
use std::fmt;
use std::sync::{Arc, Mutex};

/// One version travelling down the chain, with payload `T` (`T` starts
/// as a storage path at the Source and ends as an `Arc<dyn Loader>` at
/// the Manager — §2.1 "templated by the type of data T").
pub struct ServableData<T> {
    pub id: ServableId,
    /// Payload, or the error that occurred producing it (errors flow to
    /// the manager so it can surface them per-version).
    pub payload: anyhow::Result<T>,
}

impl<T> ServableData<T> {
    pub fn ok(id: ServableId, payload: T) -> Self {
        ServableData { id, payload: Ok(payload) }
    }

    pub fn err(id: ServableId, e: anyhow::Error) -> Self {
        ServableData { id, payload: Err(e) }
    }
}

impl<T> fmt::Debug for ServableData<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "ServableData({}, {})",
            self.id,
            if self.payload.is_ok() { "ok" } else { "err" }
        )
    }
}

/// Receiver half of the aspired-versions API.
pub trait AspiredVersionsCallback<T>: Send + Sync {
    /// Replace the aspired-version set for `servable_name` with
    /// `versions`. Empty list = aspire nothing (unload all).
    fn set_aspired_versions(&self, servable_name: &str, versions: Vec<ServableData<T>>);
}

/// Emitter half: anything that discovers servable versions.
///
/// Sources are connected with [`connect_source`]; after connection they
/// must (eventually) emit their current aspired state.
pub trait Source<T>: Send {
    fn set_aspired_versions_callback(&mut self, cb: Arc<dyn AspiredVersionsCallback<T>>);
}

/// Wire a source to a downstream callback (adapter, router or manager).
pub fn connect_source<T, S: Source<T> + ?Sized>(
    source: &mut S,
    cb: Arc<dyn AspiredVersionsCallback<T>>,
) {
    source.set_aspired_versions_callback(cb);
}

/// Test/diagnostic sink that records every call.
#[derive(Default)]
pub struct RecordingCallback<T> {
    pub calls: Mutex<Vec<(String, Vec<ServableData<T>>)>>,
}

impl<T> RecordingCallback<T> {
    pub fn new() -> Arc<Self> {
        Arc::new(RecordingCallback { calls: Mutex::new(Vec::new()) })
    }

    /// Latest aspired version numbers for `name`.
    pub fn latest_for(&self, name: &str) -> Option<Vec<u64>> {
        self.calls
            .lock()
            .unwrap()
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.iter().map(|d| d.id.version).collect())
    }

    pub fn call_count(&self) -> usize {
        self.calls.lock().unwrap().len()
    }
}

impl<T: Send> AspiredVersionsCallback<T> for RecordingCallback<T> {
    fn set_aspired_versions(&self, servable_name: &str, versions: Vec<ServableData<T>>) {
        self.calls
            .lock()
            .unwrap()
            .push((servable_name.to_string(), versions));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn servable_data_constructors() {
        let ok = ServableData::ok(ServableId::new("m", 1), 5u32);
        assert_eq!(*ok.payload.as_ref().unwrap(), 5);
        let err = ServableData::<u32>::err(
            ServableId::new("m", 2),
            anyhow::anyhow!("gone"),
        );
        assert!(err.payload.is_err());
        assert_eq!(format!("{err:?}"), "ServableData(m:2, err)");
    }

    #[test]
    fn recording_callback_tracks_latest() {
        let cb = RecordingCallback::<u32>::new();
        cb.set_aspired_versions("m", vec![ServableData::ok(ServableId::new("m", 1), 0)]);
        cb.set_aspired_versions(
            "m",
            vec![
                ServableData::ok(ServableId::new("m", 1), 0),
                ServableData::ok(ServableId::new("m", 2), 0),
            ],
        );
        cb.set_aspired_versions("other", vec![]);
        assert_eq!(cb.latest_for("m"), Some(vec![1, 2]));
        assert_eq!(cb.latest_for("other"), Some(vec![]));
        assert_eq!(cb.latest_for("absent"), None);
        assert_eq!(cb.call_count(), 3);
    }
}
