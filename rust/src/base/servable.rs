//! Servable identity, the type-erased servable box, and handles.

use super::reclaim::Reclaimer;
use std::any::Any;
use std::fmt;
use std::sync::Arc;

/// `(name, version)` — the unit of loading, serving and unloading.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ServableId {
    pub name: String,
    pub version: u64,
}

impl ServableId {
    pub fn new(name: impl Into<String>, version: u64) -> Self {
        ServableId { name: name.into(), version }
    }
}

impl fmt::Display for ServableId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.name, self.version)
    }
}

/// The black-box servable: the paper's "safe `void*`-like construct".
///
/// Managers and the lifecycle chain never look inside; inference
/// handlers downcast to the concrete type they expect
/// (`HloServable`, `TableServable`, …).
pub type ServableBox = Arc<dyn Any + Send + Sync>;

/// A checked-out reference to a loaded servable.
///
/// §2.1.2: *"Custom reference-counted servable handles that ensure the
/// freeing of memory for no-longer-wanted servables occurs in a manager
/// thread, not an inference thread."* Dropping a handle never frees the
/// servable inline: the inner `Arc` is shipped to the manager's
/// [`Reclaimer`] thread, where the final drop (and the multi-hundred-MB
/// `free()` it implies) happens off the request path.
pub struct ServableHandle<T: Send + Sync + 'static> {
    id: ServableId,
    // `Option` so Drop can move it out. The typed Arc shares the
    // allocation with the original box, so it alone keeps the servable
    // alive (no second reference needed — hot-path optimization, see
    // EXPERIMENTS.md §Perf).
    typed: Option<Arc<T>>,
    reclaimer: Reclaimer,
}

impl<T: Send + Sync + 'static> ServableHandle<T> {
    /// Downcast a servable box into a typed handle. On type mismatch
    /// the box is handed back untouched.
    pub fn new(
        id: ServableId,
        raw: ServableBox,
        reclaimer: Reclaimer,
    ) -> Result<Self, ServableBox> {
        match Arc::downcast::<T>(raw) {
            Ok(typed) => Ok(ServableHandle { id, typed: Some(typed), reclaimer }),
            Err(raw) => Err(raw),
        }
    }

    pub fn id(&self) -> &ServableId {
        &self.id
    }
}

impl<T: Send + Sync + 'static> std::ops::Deref for ServableHandle<T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.typed.as_ref().expect("handle not yet dropped")
    }
}

impl<T: Send + Sync + 'static> Drop for ServableHandle<T> {
    fn drop(&mut self) {
        // The ref goes to the reclaim thread; if we were the last
        // holder, the servable's memory is freed there, not here.
        if let Some(t) = self.typed.take() {
            self.reclaimer.defer(t);
        }
    }
}

impl<T: Send + Sync + 'static> fmt::Debug for ServableHandle<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ServableHandle({})", self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn servable_id_display_order() {
        let a = ServableId::new("m", 1);
        let b = ServableId::new("m", 2);
        assert!(a < b);
        assert_eq!(a.to_string(), "m:1");
    }

    #[test]
    fn handle_derefs_to_value() {
        let reclaimer = Reclaimer::start_for_test();
        let raw: ServableBox = Arc::new(42u64);
        let h =
            ServableHandle::<u64>::new(ServableId::new("x", 1), raw, reclaimer.clone())
                .ok()
                .unwrap();
        assert_eq!(*h, 42);
        assert_eq!(h.id().version, 1);
    }

    #[test]
    fn downcast_failure_returns_raw() {
        let reclaimer = Reclaimer::start_for_test();
        let raw: ServableBox = Arc::new("not a u64".to_string());
        assert!(ServableHandle::<u64>::new(ServableId::new("x", 1), raw, reclaimer)
            .is_err());
    }

    #[test]
    fn drop_defers_to_reclaimer_thread() {
        static DROPPED_ON: AtomicUsize = AtomicUsize::new(0);
        struct Probe;
        impl Drop for Probe {
            fn drop(&mut self) {
                let on_reclaim =
                    std::thread::current().name().map_or(false, |n| n.contains("reclaim"));
                DROPPED_ON.store(if on_reclaim { 1 } else { 2 }, Ordering::SeqCst);
            }
        }
        let reclaimer = Reclaimer::start_for_test();
        let raw: ServableBox = Arc::new(Probe);
        let h = ServableHandle::<Probe>::new(ServableId::new("p", 1), raw, reclaimer.clone())
            .ok()
            .unwrap();
        drop(h); // last refs -> reclaim thread
        reclaimer.flush();
        assert_eq!(
            DROPPED_ON.load(Ordering::SeqCst),
            1,
            "final drop must happen on the reclaim thread"
        );
    }
}
