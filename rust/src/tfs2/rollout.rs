//! Health-gated rollouts (§2.1.1 automated): the paper's canarying and
//! rollback workflows exist as manual Controller verbs; this module
//! closes the loop. A declarative [`RolloutPolicy`] is evaluated every
//! sync tick against *windowed* health scraped from the fleet
//! ([`super::synchronizer::Synchronizer::scrape_health`]): the canary
//! fraction ramps while healthy, the version promotes after a bake
//! period, and a gate breach auto-rolls back — the stable version keeps
//! serving throughout, and the rollback reason surfaces in
//! `GET /v1/models` via the `SetRolloutStatus` push.
//!
//! The state machine itself ([`evaluate`]) is a pure function of
//! (state, clock, health) so every transition is unit-testable without
//! sockets; [`RolloutEngine`] adds the per-model bookkeeping, and
//! [`super::fleet::Fleet`] applies the emitted [`RolloutAction`]s to
//! the Controller and Router.

use super::synchronizer::VersionHealth;
use crate::util::clock::Clock;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Declarative rollout policy: how fast to ramp, how long to bake,
/// and the health gates that trigger auto-rollback.
#[derive(Debug, Clone)]
pub struct RolloutPolicy {
    /// Canary traffic fractions walked one step per healthy evaluation
    /// tick (e.g. `[0.05, 0.25, 0.5]`). The final fraction holds
    /// during the bake period.
    pub canary_fraction_ramp: Vec<f64>,
    /// How long the canary must stay healthy at the final fraction
    /// before promotion.
    pub bake_ms: u64,
    /// Gate: windowed canary error rate above this rolls back.
    pub max_error_rate: f64,
    /// Gate: canary windowed p99 above `stable_p99 × this` rolls back
    /// (skipped while the stable side lacks `min_requests` of data).
    pub max_p99_vs_stable: f64,
    /// Gates evaluate only once the canary window holds at least this
    /// many requests — no traffic is not evidence of health *or* harm.
    pub min_requests: u64,
}

impl Default for RolloutPolicy {
    fn default() -> Self {
        RolloutPolicy {
            canary_fraction_ramp: vec![0.05, 0.25, 0.5],
            bake_ms: 2_000,
            max_error_rate: 0.1,
            max_p99_vs_stable: 3.0,
            min_requests: 5,
        }
    }
}

impl RolloutPolicy {
    /// Ramp length, treating an empty ramp as one 50% step.
    fn steps(&self) -> usize {
        self.canary_fraction_ramp.len().max(1)
    }

    /// Canary fraction at `step` (clamped into [0, 1]).
    fn fraction_at(&self, step: usize) -> f64 {
        self.canary_fraction_ramp
            .get(step)
            .copied()
            .unwrap_or(0.5)
            .clamp(0.0, 1.0)
    }
}

/// Where a rollout currently stands.
#[derive(Debug, Clone, PartialEq)]
pub enum RolloutPhase {
    /// Canary version not yet ready on every replica; no traffic split.
    Loading,
    /// Serving `canary_fraction_ramp[step]` of traffic on the canary.
    Ramping { step: usize },
    /// Final fraction held; promotes once `bake_ms` elapses.
    Baking { since_ns: u64 },
    /// Terminal: the canary became the sole primary.
    Promoted,
    /// Terminal: a health gate fired; the stable version was restored.
    RolledBack { reason: String },
}

/// What the fleet must do after an evaluation tick.
#[derive(Debug, Clone, PartialEq)]
pub enum RolloutAction {
    /// Install (or retune) the canary traffic split at `fraction`.
    SetSplit { fraction: f64 },
    /// Promote the canary to sole primary (controller verb + labels).
    Promote,
    /// Demote the canary, restore the stable primary, record `reason`.
    Rollback { reason: String },
}

/// One model's in-flight rollout.
#[derive(Debug, Clone)]
pub struct RolloutState {
    pub model: String,
    pub stable: u64,
    pub canary: u64,
    pub policy: RolloutPolicy,
    pub phase: RolloutPhase,
}

/// One evaluation tick, as a pure function: current state + clock +
/// scraped health in, next phase + actions out. Gates are checked in
/// every non-terminal phase that serves canary traffic; a breach wins
/// over any ramp/bake progress in the same tick.
pub fn evaluate(
    state: &RolloutState,
    now_ns: u64,
    canary_ready: bool,
    canary: &VersionHealth,
    stable: &VersionHealth,
) -> (RolloutPhase, Vec<RolloutAction>) {
    let policy = &state.policy;
    // Health gates: only with enough canary traffic in the window.
    let breach = if canary.requests >= policy.min_requests {
        if canary.error_rate() > policy.max_error_rate {
            Some(format!(
                "canary v{} error-rate {:.2} > {:.2} (window: {}/{} failed)",
                state.canary,
                canary.error_rate(),
                policy.max_error_rate,
                canary.errors,
                canary.requests,
            ))
        } else if stable.requests >= policy.min_requests
            && stable.p99_ns > 0.0
            && canary.p99_ns > stable.p99_ns * policy.max_p99_vs_stable
        {
            Some(format!(
                "canary v{} p99 {:.1}ms > {:.1}× stable p99 {:.1}ms",
                state.canary,
                canary.p99_ns / 1e6,
                policy.max_p99_vs_stable,
                stable.p99_ns / 1e6,
            ))
        } else {
            None
        }
    } else {
        None
    };

    match &state.phase {
        RolloutPhase::Promoted | RolloutPhase::RolledBack { .. } => {
            (state.phase.clone(), vec![])
        }
        RolloutPhase::Loading => {
            if !canary_ready {
                return (RolloutPhase::Loading, vec![]);
            }
            // First traffic: open the split at the first ramp step.
            (
                RolloutPhase::Ramping { step: 0 },
                vec![RolloutAction::SetSplit { fraction: policy.fraction_at(0) }],
            )
        }
        RolloutPhase::Ramping { step } => {
            if let Some(reason) = breach {
                return (
                    RolloutPhase::RolledBack { reason: reason.clone() },
                    vec![RolloutAction::Rollback { reason }],
                );
            }
            // Advance only on evidence: a tick with too little canary
            // traffic holds the current step rather than ramping blind.
            if canary.requests < policy.min_requests {
                return (RolloutPhase::Ramping { step: *step }, vec![]);
            }
            let next = step + 1;
            if next < policy.steps() {
                (
                    RolloutPhase::Ramping { step: next },
                    vec![RolloutAction::SetSplit { fraction: policy.fraction_at(next) }],
                )
            } else {
                // Final fraction stays installed while baking.
                (RolloutPhase::Baking { since_ns: now_ns }, vec![])
            }
        }
        RolloutPhase::Baking { since_ns } => {
            if let Some(reason) = breach {
                return (
                    RolloutPhase::RolledBack { reason: reason.clone() },
                    vec![RolloutAction::Rollback { reason }],
                );
            }
            if now_ns >= since_ns + state.policy.bake_ms * 1_000_000 {
                (RolloutPhase::Promoted, vec![RolloutAction::Promote])
            } else {
                (RolloutPhase::Baking { since_ns: *since_ns }, vec![])
            }
        }
    }
}

/// Per-model rollout bookkeeping. Terminal states stay queryable (the
/// rollback reason must outlive the rollout) until the next `begin`
/// for the same model replaces them.
pub struct RolloutEngine {
    clock: Arc<dyn Clock>,
    active: Mutex<HashMap<String, RolloutState>>,
}

impl RolloutEngine {
    pub fn new(clock: Arc<dyn Clock>) -> Self {
        RolloutEngine { clock, active: Mutex::new(HashMap::new()) }
    }

    /// Start tracking a rollout (phase `Loading`). Replaces any prior
    /// rollout for the model, including terminal ones.
    pub fn begin(&self, model: &str, stable: u64, canary: u64, policy: RolloutPolicy) {
        self.active.lock().unwrap().insert(
            model.to_string(),
            RolloutState {
                model: model.to_string(),
                stable,
                canary,
                policy,
                phase: RolloutPhase::Loading,
            },
        );
    }

    /// Evaluate one tick for `model`; returns the actions the caller
    /// must apply. No-op (empty) for models without an active rollout.
    pub fn tick(
        &self,
        model: &str,
        canary_ready: bool,
        canary: &VersionHealth,
        stable: &VersionHealth,
    ) -> Vec<RolloutAction> {
        let mut active = self.active.lock().unwrap();
        let Some(state) = active.get_mut(model) else { return vec![] };
        let (phase, actions) =
            evaluate(state, self.clock.now_nanos(), canary_ready, canary, stable);
        state.phase = phase;
        actions
    }

    /// Current state of a model's rollout, if one was ever begun.
    pub fn state(&self, model: &str) -> Option<RolloutState> {
        self.active.lock().unwrap().get(model).cloned()
    }

    /// Models with a rollout still in a non-terminal phase.
    pub fn in_flight(&self) -> Vec<String> {
        let mut models: Vec<String> = self
            .active
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, s)| {
                !matches!(
                    s.phase,
                    RolloutPhase::Promoted | RolloutPhase::RolledBack { .. }
                )
            })
            .map(|(m, _)| m.clone())
            .collect();
        models.sort();
        models
    }

    /// Human-readable status for `SetRolloutStatus` / `GET /v1/models`.
    pub fn status_line(&self, model: &str) -> Option<String> {
        let active = self.active.lock().unwrap();
        let s = active.get(model)?;
        Some(match &s.phase {
            RolloutPhase::Loading => {
                format!("loading: canary v{} (stable v{})", s.canary, s.stable)
            }
            RolloutPhase::Ramping { step } => format!(
                "ramping: canary v{} step {}/{} ({:.0}%)",
                s.canary,
                step + 1,
                s.policy.steps(),
                s.policy.fraction_at(*step) * 100.0
            ),
            RolloutPhase::Baking { .. } => format!(
                "baking: canary v{} at {:.0}%",
                s.canary,
                s.policy.fraction_at(s.policy.steps() - 1) * 100.0
            ),
            RolloutPhase::Promoted => format!("promoted: v{}", s.canary),
            RolloutPhase::RolledBack { reason } => {
                format!("rolled_back: {reason} (stable v{} restored)", s.stable)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(phase: RolloutPhase) -> RolloutState {
        RolloutState {
            model: "m".into(),
            stable: 1,
            canary: 2,
            policy: RolloutPolicy::default(),
            phase,
        }
    }

    fn health(requests: u64, errors: u64, p99_ns: f64) -> VersionHealth {
        VersionHealth { requests, errors, p99_ns }
    }

    const HEALTHY: VersionHealth = VersionHealth { requests: 100, errors: 0, p99_ns: 1e6 };

    #[test]
    fn loading_waits_for_ready_then_opens_first_step() {
        let s = state(RolloutPhase::Loading);
        let (phase, actions) = evaluate(&s, 0, false, &HEALTHY, &HEALTHY);
        assert_eq!(phase, RolloutPhase::Loading);
        assert!(actions.is_empty());
        let (phase, actions) = evaluate(&s, 0, true, &health(0, 0, 0.0), &HEALTHY);
        assert_eq!(phase, RolloutPhase::Ramping { step: 0 });
        assert_eq!(actions, vec![RolloutAction::SetSplit { fraction: 0.05 }]);
    }

    #[test]
    fn ramp_advances_per_healthy_tick_then_bakes_then_promotes() {
        let mut s = state(RolloutPhase::Ramping { step: 0 });
        let (phase, actions) = evaluate(&s, 0, true, &HEALTHY, &HEALTHY);
        assert_eq!(phase, RolloutPhase::Ramping { step: 1 });
        assert_eq!(actions, vec![RolloutAction::SetSplit { fraction: 0.25 }]);
        s.phase = phase;
        let (phase, actions) = evaluate(&s, 0, true, &HEALTHY, &HEALTHY);
        assert_eq!(phase, RolloutPhase::Ramping { step: 2 });
        assert_eq!(actions, vec![RolloutAction::SetSplit { fraction: 0.5 }]);
        s.phase = phase;
        // Final step: healthy tick moves to baking (split stays).
        let (phase, actions) = evaluate(&s, 7, true, &HEALTHY, &HEALTHY);
        assert_eq!(phase, RolloutPhase::Baking { since_ns: 7 });
        assert!(actions.is_empty());
        s.phase = phase;
        // Bake not yet elapsed: hold.
        let bake_ns = s.policy.bake_ms * 1_000_000;
        let (phase, actions) = evaluate(&s, 7 + bake_ns - 1, true, &HEALTHY, &HEALTHY);
        assert_eq!(phase, RolloutPhase::Baking { since_ns: 7 });
        assert!(actions.is_empty());
        // Bake complete: promote.
        let (phase, actions) = evaluate(&s, 7 + bake_ns, true, &HEALTHY, &HEALTHY);
        assert_eq!(phase, RolloutPhase::Promoted);
        assert_eq!(actions, vec![RolloutAction::Promote]);
        // Terminal: further ticks are inert.
        s.phase = phase;
        let (phase, actions) = evaluate(&s, u64::MAX, true, &health(10, 10, 1e9), &HEALTHY);
        assert_eq!(phase, RolloutPhase::Promoted);
        assert!(actions.is_empty());
    }

    #[test]
    fn error_rate_breach_rolls_back_from_ramp_and_bake() {
        // 40% failures > 10% gate.
        let sick = health(20, 8, 1e6);
        for phase in [RolloutPhase::Ramping { step: 1 }, RolloutPhase::Baking { since_ns: 0 }] {
            let s = state(phase);
            let (next, actions) = evaluate(&s, 1, true, &sick, &HEALTHY);
            match (&next, actions.as_slice()) {
                (
                    RolloutPhase::RolledBack { reason },
                    [RolloutAction::Rollback { reason: r }],
                ) => {
                    assert_eq!(reason, r);
                    assert!(reason.contains("error-rate"), "{reason}");
                    assert!(reason.contains("v2"), "{reason}");
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn p99_breach_rolls_back_only_with_stable_baseline() {
        // Canary 10× slower than stable: breaches the 3× gate.
        let slow = health(50, 0, 50e6);
        let s = state(RolloutPhase::Baking { since_ns: 0 });
        let (next, actions) = evaluate(&s, 1, true, &slow, &health(100, 0, 5e6));
        assert!(matches!(next, RolloutPhase::RolledBack { .. }), "{next:?}");
        assert_eq!(actions.len(), 1);
        // Without a stable baseline (no stable traffic in window), the
        // relative gate cannot fire — no false rollback.
        let (next, actions) = evaluate(&s, 1, true, &slow, &health(0, 0, 0.0));
        assert_eq!(next, RolloutPhase::Baking { since_ns: 0 });
        assert!(actions.is_empty());
    }

    #[test]
    fn too_little_canary_traffic_holds_everything() {
        // 4 requests, all failed — still below min_requests=5: neither
        // a rollback nor a ramp advance may happen on that evidence.
        let sparse = health(4, 4, 1e9);
        let s = state(RolloutPhase::Ramping { step: 1 });
        let (next, actions) = evaluate(&s, 1, true, &sparse, &HEALTHY);
        assert_eq!(next, RolloutPhase::Ramping { step: 1 });
        assert!(actions.is_empty());
    }

    #[test]
    fn engine_tracks_state_and_status_lines() {
        let clock = crate::util::clock::ManualClock::new();
        let engine = RolloutEngine::new(clock.clone());
        assert!(engine.tick("m", true, &HEALTHY, &HEALTHY).is_empty());
        assert_eq!(engine.status_line("m"), None);

        engine.begin("m", 1, 2, RolloutPolicy::default());
        assert_eq!(engine.in_flight(), vec!["m".to_string()]);
        assert!(engine.status_line("m").unwrap().starts_with("loading"));
        // Ready → first split.
        let actions = engine.tick("m", true, &health(0, 0, 0.0), &HEALTHY);
        assert_eq!(actions, vec![RolloutAction::SetSplit { fraction: 0.05 }]);
        assert!(engine.status_line("m").unwrap().contains("step 1/3"));
        // Sick canary → rollback action, terminal state keeps reason.
        let actions = engine.tick("m", true, &health(50, 40, 1e6), &HEALTHY);
        assert!(matches!(actions.as_slice(), [RolloutAction::Rollback { .. }]));
        let line = engine.status_line("m").unwrap();
        assert!(line.starts_with("rolled_back:"), "{line}");
        assert!(line.contains("stable v1 restored"), "{line}");
        assert!(engine.in_flight().is_empty());
        // Terminal states are inert but queryable until the next begin.
        assert!(engine.tick("m", true, &HEALTHY, &HEALTHY).is_empty());
        engine.begin("m", 1, 3, RolloutPolicy::default());
        assert!(engine.status_line("m").unwrap().contains("v3"));
    }
}
