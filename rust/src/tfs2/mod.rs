//! TFS² — the hosted model-serving service (paper §3.1, Figure 2).
//!
//! "Users issue high-level commands such as 'add model', 'remove
//! model', and 'add model version'. The TFS² infrastructure takes care
//! of the rest, including assigning each model to one of a suite of
//! serving jobs based on resource fit."
//!
//! * [`store`] — the Spanner stand-in: durable (WAL + snapshot),
//!   transactional, leader + simulated replicas.
//! * [`binpack`] — RAM-estimate bin-packing (best-fit-decreasing, with
//!   a first-fit baseline for experiment T7).
//! * [`controller`] — add/remove model & version, canary/rollback,
//!   placement; all state transactional in the store.
//! * [`synchronizer`] — per-DC reconciler: pushes aspired versions to
//!   serving jobs over RPC, collects load status, publishes the routing
//!   table.
//! * [`router`] — forwards inference requests to the right job, with
//!   hedged backup requests (§3.1).
//! * [`autoscaler`] — reactive replica scaling from scraped metrics
//!   (lane depth, queue-delay SLO, admission sheds).
//! * [`rollout`] — health-gated canary rollouts: declarative policy,
//!   ramp/bake/promote state machine, auto-rollback on gate breach.
//! * [`cluster`] — in-process multi-job cluster over real sockets.
//! * [`fleet`] — the assembled control plane: deploy → reconcile →
//!   autoscale → route, one handle.

pub mod autoscaler;
pub mod binpack;
pub mod cluster;
pub mod controller;
pub mod fleet;
pub mod rollout;
pub mod router;
pub mod store;
pub mod synchronizer;
