//! RAM-fit placement (§3.1: the Controller "estimates the RAM required
//! to serve a given model and selects a serving job that has enough
//! memory capacity").
//!
//! Primary policy: **best-fit** (tightest remaining capacity that
//! fits) with a decreasing-size batch variant; **first-fit** is the
//! baseline for experiment T7 (`benches/bench_binpack.rs`).

/// A serving job's capacity view.
#[derive(Debug, Clone, PartialEq)]
pub struct Bin {
    pub id: String,
    pub capacity: u64,
    pub used: u64,
}

impl Bin {
    pub fn new(id: impl Into<String>, capacity: u64) -> Self {
        Bin { id: id.into(), capacity, used: 0 }
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used)
    }
}

/// Best-fit: the job whose remaining capacity is smallest but still
/// fits. Returns the chosen bin index.
pub fn best_fit(bins: &[Bin], size: u64) -> Option<usize> {
    bins.iter()
        .enumerate()
        .filter(|(_, b)| b.free() >= size)
        .min_by_key(|(_, b)| b.free())
        .map(|(i, _)| i)
}

/// First-fit baseline: the first job that fits.
pub fn first_fit(bins: &[Bin], size: u64) -> Option<usize> {
    bins.iter().position(|b| b.free() >= size)
}

/// Place a batch of (item id, size) with best-fit-decreasing.
/// Returns (item id → bin id) for placed items and the ids that did
/// not fit anywhere.
pub fn best_fit_decreasing(
    bins: &mut [Bin],
    items: &[(String, u64)],
) -> (Vec<(String, String)>, Vec<String>) {
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(items[i].1));
    let mut placed = Vec::new();
    let mut failed = Vec::new();
    for i in order {
        let (id, size) = &items[i];
        match best_fit(bins, *size) {
            Some(b) => {
                bins[b].used += size;
                placed.push((id.clone(), bins[b].id.clone()));
            }
            None => failed.push(id.clone()),
        }
    }
    (placed, failed)
}

/// Aggregate utilization of used bins (placed volume / capacity of
/// bins that hold at least one item).
pub fn utilization(bins: &[Bin]) -> f64 {
    let (used, cap) = bins
        .iter()
        .filter(|b| b.used > 0)
        .fold((0u64, 0u64), |(u, c), b| (u + b.used, c + b.capacity));
    if cap == 0 {
        0.0
    } else {
        used as f64 / cap as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn bins(caps: &[u64]) -> Vec<Bin> {
        caps.iter()
            .enumerate()
            .map(|(i, &c)| Bin::new(format!("job-{i}"), c))
            .collect()
    }

    #[test]
    fn best_fit_picks_tightest() {
        let b = bins(&[100, 50, 80]);
        assert_eq!(best_fit(&b, 40), Some(1)); // 50 is tightest fit
        assert_eq!(best_fit(&b, 60), Some(2));
        assert_eq!(best_fit(&b, 90), Some(0));
        assert_eq!(best_fit(&b, 200), None);
    }

    #[test]
    fn first_fit_picks_first() {
        let b = bins(&[100, 50, 80]);
        assert_eq!(first_fit(&b, 40), Some(0));
    }

    #[test]
    fn bfd_places_all_when_space_exists() {
        let mut b = bins(&[100, 100]);
        let items: Vec<(String, u64)> =
            [60u64, 60, 40, 40].iter().enumerate().map(|(i, &s)| (format!("m{i}"), s)).collect();
        let (placed, failed) = best_fit_decreasing(&mut b, &items);
        // 60+40 in each bin: BFD succeeds where naive order can fail.
        assert_eq!(placed.len(), 4);
        assert!(failed.is_empty());
        assert!(b.iter().all(|bin| bin.used == 100));
        assert_eq!(utilization(&b), 1.0);
    }

    #[test]
    fn bfd_reports_misfits() {
        let mut b = bins(&[50]);
        let items = vec![("big".to_string(), 80u64), ("ok".to_string(), 30)];
        let (placed, failed) = best_fit_decreasing(&mut b, &items);
        assert_eq!(placed, vec![("ok".to_string(), "job-0".to_string())]);
        assert_eq!(failed, vec!["big".to_string()]);
    }

    #[test]
    fn capacity_never_exceeded_property() {
        forall::<(u64, Vec<u64>), _>("binpack respects capacity", |(seed, sizes)| {
            let mut rng = Rng::new(*seed);
            let mut b: Vec<Bin> = (0..rng.range(1, 6))
                .map(|i| Bin::new(format!("j{i}"), rng.next_below(1000) + 1))
                .collect();
            let items: Vec<(String, u64)> = sizes
                .iter()
                .take(20)
                .enumerate()
                .map(|(i, s)| (format!("m{i}"), s % 500))
                .collect();
            let (placed, failed) = best_fit_decreasing(&mut b, &items);
            placed.len() + failed.len() == items.len()
                && b.iter().all(|bin| bin.used <= bin.capacity)
        });
    }

    #[test]
    fn bfd_beats_or_matches_first_fit_on_fragmentation() {
        // Classic case: first-fit in arrival order wastes space that
        // best-fit-decreasing recovers.
        let items: Vec<(String, u64)> = [35u64, 60, 35, 60, 30, 40]
            .iter()
            .enumerate()
            .map(|(i, &s)| (format!("m{i}"), s))
            .collect();
        let mut bfd_bins = bins(&[100, 100, 100]);
        let (bfd_placed, bfd_failed) = best_fit_decreasing(&mut bfd_bins, &items);
        assert!(bfd_failed.is_empty());
        assert_eq!(bfd_placed.len(), 6);

        // First-fit in arrival order.
        let mut ff_bins = bins(&[100, 100, 100]);
        let mut ff_failed = 0;
        for (_, size) in &items {
            match first_fit(&ff_bins, *size) {
                Some(i) => ff_bins[i].used += size,
                None => ff_failed += 1,
            }
        }
        let bins_used_bfd = bfd_bins.iter().filter(|b| b.used > 0).count();
        let bins_used_ff = ff_bins.iter().filter(|b| b.used > 0).count() + ff_failed;
        assert!(bins_used_bfd <= bins_used_ff);
    }
}
