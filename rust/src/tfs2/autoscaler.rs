//! Reactive autoscaling (§3.1): "experimental launches and gradual
//! production traffic variations are handled automatically by a
//! separate system that reactively autoscales each serving job
//! (dynamically adding and removing job replicas as load fluctuates)".
//!
//! Pure decision logic (the cluster applies the decisions): per-job
//! target replica counts from observed load, with hysteresis and
//! cooldown so flapping traffic doesn't flap replicas.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Target per-replica load (e.g. qps) the scaler aims for.
    pub target_load_per_replica: f64,
    /// Scale up when load/replica exceeds target * up_threshold.
    pub up_threshold: f64,
    /// Scale down when load/replica falls below target * down_threshold.
    pub down_threshold: f64,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Ticks to wait after a scaling action before acting again.
    pub cooldown_ticks: u32,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            target_load_per_replica: 100.0,
            up_threshold: 1.2,
            down_threshold: 0.5,
            min_replicas: 1,
            max_replicas: 16,
            cooldown_ticks: 3,
        }
    }
}

#[derive(Debug, Default)]
struct JobState {
    replicas: usize,
    cooldown: u32,
}

/// One scaling decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decision {
    pub job: String,
    pub from: usize,
    pub to: usize,
}

pub struct Autoscaler {
    config: AutoscalerConfig,
    jobs: HashMap<String, JobState>,
}

impl Autoscaler {
    pub fn new(config: AutoscalerConfig) -> Self {
        Autoscaler { config, jobs: HashMap::new() }
    }

    /// Register a job with its current replica count.
    pub fn track(&mut self, job: &str, replicas: usize) {
        self.jobs.insert(
            job.to_string(),
            JobState { replicas: replicas.max(self.config.min_replicas), cooldown: 0 },
        );
    }

    pub fn replicas(&self, job: &str) -> usize {
        self.jobs.get(job).map_or(0, |s| s.replicas)
    }

    /// One tick: feed per-job total load, get scaling decisions.
    pub fn tick(&mut self, loads: &HashMap<String, f64>) -> Vec<Decision> {
        let mut decisions = Vec::new();
        for (job, state) in self.jobs.iter_mut() {
            if state.cooldown > 0 {
                state.cooldown -= 1;
                continue;
            }
            let load = loads.get(job).copied().unwrap_or(0.0);
            let per_replica = load / state.replicas.max(1) as f64;
            let target = self.config.target_load_per_replica;
            let to = if per_replica > target * self.config.up_threshold {
                // Scale to the count that brings per-replica load to
                // target (ceil), bounded.
                ((load / target).ceil() as usize)
                    .clamp(state.replicas + 1, self.config.max_replicas)
            } else if per_replica < target * self.config.down_threshold
                && state.replicas > self.config.min_replicas
            {
                ((load / target).ceil() as usize)
                    .clamp(self.config.min_replicas, state.replicas - 1)
            } else {
                continue;
            };
            if to != state.replicas {
                decisions.push(Decision { job: job.clone(), from: state.replicas, to });
                state.replicas = to;
                state.cooldown = self.config.cooldown_ticks;
            }
        }
        decisions.sort_by(|a, b| a.job.cmp(&b.job));
        decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler() -> Autoscaler {
        let mut a = Autoscaler::new(AutoscalerConfig {
            target_load_per_replica: 100.0,
            up_threshold: 1.2,
            down_threshold: 0.5,
            min_replicas: 1,
            max_replicas: 8,
            cooldown_ticks: 2,
        });
        a.track("j", 1);
        a
    }

    fn load(v: f64) -> HashMap<String, f64> {
        HashMap::from([("j".to_string(), v)])
    }

    #[test]
    fn scales_up_under_load() {
        let mut a = scaler();
        let d = a.tick(&load(450.0));
        assert_eq!(d, vec![Decision { job: "j".into(), from: 1, to: 5 }]);
        assert_eq!(a.replicas("j"), 5);
    }

    #[test]
    fn steady_load_no_action() {
        let mut a = scaler();
        assert!(a.tick(&load(100.0)).is_empty());
        assert!(a.tick(&load(110.0)).is_empty()); // within hysteresis band
    }

    #[test]
    fn scales_down_when_idle() {
        let mut a = scaler();
        a.tick(&load(800.0)); // up to 8
        assert_eq!(a.replicas("j"), 8);
        // wait out cooldown
        a.tick(&load(100.0));
        a.tick(&load(100.0));
        let d = a.tick(&load(100.0));
        assert_eq!(d.len(), 1);
        assert!(d[0].to < 8);
    }

    #[test]
    fn cooldown_suppresses_flapping() {
        let mut a = scaler();
        assert_eq!(a.tick(&load(450.0)).len(), 1);
        // Immediately dropping load must NOT scale down during cooldown.
        assert!(a.tick(&load(10.0)).is_empty());
        assert!(a.tick(&load(10.0)).is_empty());
        // Cooldown expired: now it may act.
        assert_eq!(a.tick(&load(10.0)).len(), 1);
    }

    #[test]
    fn respects_min_max() {
        let mut a = scaler();
        a.tick(&load(1e9));
        assert_eq!(a.replicas("j"), 8); // max
        for _ in 0..20 {
            a.tick(&load(0.0));
        }
        assert_eq!(a.replicas("j"), 1); // min, never 0
    }

    #[test]
    fn multiple_jobs_independent() {
        let mut a = Autoscaler::new(AutoscalerConfig::default());
        a.track("a", 1);
        a.track("b", 1);
        let loads =
            HashMap::from([("a".to_string(), 1000.0), ("b".to_string(), 50.0)]);
        let d = a.tick(&loads);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].job, "a");
    }
}
