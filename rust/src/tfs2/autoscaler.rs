//! Reactive autoscaling (§3.1): "experimental launches and gradual
//! production traffic variations are handled automatically by a
//! separate system that reactively autoscales each serving job
//! (dynamically adding and removing job replicas as load fluctuates)".
//!
//! Pure decision logic (the cluster applies the decisions): per-job
//! target replica counts from observed load, with hysteresis and
//! cooldown so flapping traffic doesn't flap replicas.
//!
//! Two entry points share one decision core:
//! * [`Autoscaler::tick`] — a scalar load per job (e.g. qps), the
//!   original interface;
//! * [`Autoscaler::tick_signals`] — structured [`LoadSignal`]s as the
//!   Synchronizer scrapes them from replicas: batching lane depth is
//!   the primary load measure, admission sheds add weighted pressure,
//!   and a queue-delay p99 above the SLO forces a scale-up even when
//!   lane depth alone looks tolerable (depth measures queued work,
//!   delay measures how long that queue actually holds requests).

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Target per-replica load (lane depth or qps) the scaler aims for.
    pub target_load_per_replica: f64,
    /// Scale up when load/replica exceeds target * up_threshold.
    pub up_threshold: f64,
    /// Scale down when load/replica falls below target * down_threshold.
    pub down_threshold: f64,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// Ticks to wait after a scaling action before acting again.
    pub cooldown_ticks: u32,
    /// Queue-delay p99 SLO: a job whose scraped queue-delay p99
    /// exceeds this scales up regardless of lane depth (signals path
    /// only). The fleet feeds the *windowed* series
    /// (`batch.*.queue_delay_ns.window.p99`) so the signal reflects
    /// recent load, not lifetime history — the cumulative series
    /// stays exported for `/metrics`. Default 50ms.
    pub queue_delay_slo_ns: f64,
    /// How much load each newly shed request adds on top of lane
    /// depth: sheds are demand the server refused, so they count as
    /// queued work that never got to queue.
    pub shed_weight: f64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            target_load_per_replica: 100.0,
            up_threshold: 1.2,
            down_threshold: 0.5,
            min_replicas: 1,
            max_replicas: 16,
            cooldown_ticks: 3,
            queue_delay_slo_ns: 5e7,
            shed_weight: 1.0,
        }
    }
}

/// Per-job load signals, as scraped by the Synchronizer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LoadSignal {
    /// Sum of batching lane depths across the job's replicas.
    pub lane_depth: f64,
    /// Worst *windowed* queue-delay p99 across the job's replicas
    /// (ns) — recent behaviour, so a long-recovered startup spike
    /// can't keep a job scaled up forever.
    pub queue_delay_p99_ns: f64,
    /// Requests shed by admission control since the last tick.
    pub shed_delta: f64,
}

#[derive(Debug, Default)]
struct JobState {
    replicas: usize,
    cooldown: u32,
}

/// One scaling decision.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Decision {
    pub job: String,
    pub from: usize,
    pub to: usize,
}

pub struct Autoscaler {
    config: AutoscalerConfig,
    jobs: HashMap<String, JobState>,
}

impl Autoscaler {
    pub fn new(config: AutoscalerConfig) -> Self {
        Autoscaler { config, jobs: HashMap::new() }
    }

    /// Register a job with its current replica count.
    pub fn track(&mut self, job: &str, replicas: usize) {
        self.jobs.insert(
            job.to_string(),
            JobState { replicas: replicas.max(self.config.min_replicas), cooldown: 0 },
        );
    }

    pub fn replicas(&self, job: &str) -> usize {
        self.jobs.get(job).map_or(0, |s| s.replicas)
    }

    /// One tick: feed per-job total load, get scaling decisions.
    pub fn tick(&mut self, loads: &HashMap<String, f64>) -> Vec<Decision> {
        let signals: HashMap<String, LoadSignal> = loads
            .iter()
            .map(|(job, &load)| {
                (job.clone(), LoadSignal { lane_depth: load, ..Default::default() })
            })
            .collect();
        self.tick_signals(&signals)
    }

    /// One tick over structured signals: load is lane depth plus
    /// weighted sheds; a queue-delay SLO breach forces a scale-up.
    pub fn tick_signals(&mut self, signals: &HashMap<String, LoadSignal>) -> Vec<Decision> {
        let mut decisions = Vec::new();
        for (job, state) in self.jobs.iter_mut() {
            if state.cooldown > 0 {
                state.cooldown -= 1;
                continue;
            }
            let signal = signals.get(job).cloned().unwrap_or_default();
            let load = signal.lane_depth + self.config.shed_weight * signal.shed_delta;
            let force_up = signal.queue_delay_p99_ns > self.config.queue_delay_slo_ns;
            let Some(to) = decide(&self.config, state.replicas, load, force_up) else {
                continue;
            };
            decisions.push(Decision { job: job.clone(), from: state.replicas, to });
            state.replicas = to;
            state.cooldown = self.config.cooldown_ticks;
        }
        decisions.sort_by(|a, b| a.job.cmp(&b.job));
        decisions
    }
}

/// The shared decision core: next replica count, or `None` to hold.
fn decide(
    config: &AutoscalerConfig,
    replicas: usize,
    load: f64,
    force_up: bool,
) -> Option<usize> {
    let per_replica = load / replicas.max(1) as f64;
    let target = config.target_load_per_replica;
    if per_replica > target * config.up_threshold || force_up {
        // Scale to the count that brings per-replica load to target
        // (ceil), always at least one step, bounded above; already at
        // max is a hold, not a decision.
        if replicas >= config.max_replicas {
            return None;
        }
        Some(((load / target).ceil() as usize).clamp(replicas + 1, config.max_replicas))
    } else if per_replica < target * config.down_threshold && replicas > config.min_replicas {
        Some(((load / target).ceil() as usize).clamp(config.min_replicas, replicas - 1))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config() -> AutoscalerConfig {
        AutoscalerConfig {
            target_load_per_replica: 100.0,
            up_threshold: 1.2,
            down_threshold: 0.5,
            min_replicas: 1,
            max_replicas: 8,
            cooldown_ticks: 2,
            ..Default::default()
        }
    }

    fn scaler() -> Autoscaler {
        let mut a = Autoscaler::new(config());
        a.track("j", 1);
        a
    }

    fn load(v: f64) -> HashMap<String, f64> {
        HashMap::from([("j".to_string(), v)])
    }

    fn signal(s: LoadSignal) -> HashMap<String, LoadSignal> {
        HashMap::from([("j".to_string(), s)])
    }

    #[test]
    fn scales_up_under_load() {
        let mut a = scaler();
        let d = a.tick(&load(450.0));
        assert_eq!(d, vec![Decision { job: "j".into(), from: 1, to: 5 }]);
        assert_eq!(a.replicas("j"), 5);
    }

    #[test]
    fn steady_load_no_action() {
        let mut a = scaler();
        assert!(a.tick(&load(100.0)).is_empty());
        assert!(a.tick(&load(110.0)).is_empty()); // within hysteresis band
    }

    #[test]
    fn scales_down_when_idle() {
        let mut a = scaler();
        a.tick(&load(800.0)); // up to 8
        assert_eq!(a.replicas("j"), 8);
        // wait out cooldown
        a.tick(&load(100.0));
        a.tick(&load(100.0));
        let d = a.tick(&load(100.0));
        assert_eq!(d.len(), 1);
        assert!(d[0].to < 8);
    }

    #[test]
    fn cooldown_suppresses_flapping() {
        let mut a = scaler();
        assert_eq!(a.tick(&load(450.0)).len(), 1);
        // Immediately dropping load must NOT scale down during cooldown.
        assert!(a.tick(&load(10.0)).is_empty());
        assert!(a.tick(&load(10.0)).is_empty());
        // Cooldown expired: now it may act.
        assert_eq!(a.tick(&load(10.0)).len(), 1);
    }

    #[test]
    fn respects_min_max() {
        let mut a = scaler();
        a.tick(&load(1e9));
        assert_eq!(a.replicas("j"), 8); // max
        for _ in 0..20 {
            a.tick(&load(0.0));
        }
        assert_eq!(a.replicas("j"), 1); // min, never 0
    }

    #[test]
    fn multiple_jobs_independent() {
        let mut a = Autoscaler::new(AutoscalerConfig::default());
        a.track("a", 1);
        a.track("b", 1);
        let loads =
            HashMap::from([("a".to_string(), 1000.0), ("b".to_string(), 50.0)]);
        let d = a.tick(&loads);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].job, "a");
    }

    #[test]
    fn slo_breach_forces_scale_up_despite_shallow_lanes() {
        let mut a = scaler();
        // Lane depth alone is comfortably under threshold…
        assert!(a
            .tick_signals(&signal(LoadSignal { lane_depth: 50.0, ..Default::default() }))
            .is_empty());
        // …but a queue-delay p99 past the SLO still adds a replica.
        let d = a.tick_signals(&signal(LoadSignal {
            lane_depth: 50.0,
            queue_delay_p99_ns: 6e7, // > 5e7 default SLO
            ..Default::default()
        }));
        assert_eq!(d, vec![Decision { job: "j".into(), from: 1, to: 2 }]);
    }

    #[test]
    fn sheds_count_as_load() {
        let mut a = scaler();
        // 60 queued + 70 refused = 130 effective load > 120 threshold.
        let d = a.tick_signals(&signal(LoadSignal {
            lane_depth: 60.0,
            shed_delta: 70.0,
            ..Default::default()
        }));
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].to, 2);
    }

    #[test]
    fn slo_breach_at_max_replicas_holds_without_panicking() {
        let mut a = scaler();
        a.tick(&load(1e9)); // pin at max (8)
        a.tick(&load(800.0));
        a.tick(&load(800.0)); // drain cooldown
        let d = a.tick_signals(&signal(LoadSignal {
            lane_depth: 800.0,
            queue_delay_p99_ns: 1e9,
            ..Default::default()
        }));
        assert!(d.is_empty());
        assert_eq!(a.replicas("j"), 8);
    }
}
