//! The TFS² Router (§3.1): forwards inference RPCs to whichever serving
//! job holds the model, "using hedged backup requests to mitigate
//! latency spikes from transient server issues or inter-request or
//! -model interference".

use crate::rpc::hedged::HedgedClient;
use crate::rpc::proto::{Request, Response};
use crate::util::metrics::Registry;
use crate::util::rcu::Rcu;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Routing table: model → replica addresses (primary rotation applied
/// per request).
type Table = HashMap<String, Vec<String>>;

pub struct Router {
    /// RCU: the table is read per request, replaced by the Synchronizer.
    table: Rcu<Table>,
    hedged: HedgedClient,
    rr: AtomicUsize,
    pub registry: Arc<Registry>,
}

impl Router {
    pub fn new(hedge_delay: Duration) -> Arc<Self> {
        Arc::new(Router {
            table: Rcu::new(Table::new()),
            hedged: HedgedClient::new(
                Arc::new(crate::rpc::client::ClientPool::new()),
                hedge_delay,
            ),
            rr: AtomicUsize::new(0),
            registry: Registry::new(),
        })
    }

    /// Install a new routing table (from [`super::synchronizer`]).
    pub fn update_table(&self, entries: Vec<(String, Vec<String>)>) {
        self.table.update(entries.into_iter().collect());
    }

    /// Replicas for a model, rotated so load spreads round-robin.
    fn replicas_for(&self, model: &str) -> Result<Vec<String>> {
        let guard = self.table.read();
        let replicas = guard
            .get(model)
            .filter(|r| !r.is_empty())
            .ok_or_else(|| anyhow!("model '{model}' not loaded anywhere"))?;
        let n = replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        Ok((0..n).map(|i| replicas[(start + i) % n].clone()).collect())
    }

    /// Route one inference request. The model name is extracted from
    /// the request; admin requests are rejected (they go through the
    /// Controller, not the data plane). A deadline envelope is looked
    /// through for extraction and forwarded whole, so the replica
    /// enforces the caller's budget.
    pub fn route(&self, req: &Request) -> Result<Response> {
        let mut inner = req;
        while let Request::WithDeadline { inner: i, .. } = inner {
            inner = i;
        }
        let model = match inner {
            Request::Predict { spec, .. }
            | Request::Classify { spec, .. }
            | Request::Regress { spec, .. }
            | Request::MultiInference { spec, .. }
            | Request::GetModelMetadata { spec } => spec.name.clone(),
            Request::Lookup { table, .. } => table.clone(),
            _ => return Err(anyhow!("router only forwards inference requests")),
        };
        let t0 = std::time::Instant::now();
        let replicas = self.replicas_for(&model)?;
        let result = self.hedged.call(&replicas, req);
        self.registry.counter("router.requests").inc();
        if result.is_err() {
            self.registry.counter("router.errors").inc();
        }
        self.registry
            .histogram("router.latency_ns")
            .record_duration(t0.elapsed());
        result
    }

    /// Route with a deadline attached: wraps the request in the wire
    /// envelope so the replica itself enforces the caller's budget
    /// (expired work is shed there, not executed and discarded here).
    pub fn route_with_deadline(&self, req: &Request, deadline_ms: u64) -> Result<Response> {
        self.route(&req.clone().with_deadline_ms(deadline_ms))
    }

    pub fn hedge_rate(&self) -> f64 {
        self.hedged.hedge_rate()
    }

    /// Models currently routable.
    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.table.read().keys().cloned().collect();
        m.sort();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use std::sync::atomic::AtomicU64;

    fn counting_job() -> (Arc<RpcServer>, Arc<AtomicU64>) {
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                Request::Regress { .. } => {
                    c.fetch_add(1, Ordering::SeqCst);
                    Response::Regress { model_version: 1, values: vec![0.0] }
                }
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap();
        (server, count)
    }

    fn regress_req() -> Request {
        Request::regress("m", None, vec![crate::inference::example::Example::new()])
    }

    #[test]
    fn routes_to_loaded_job() {
        let (job, count) = counting_job();
        let router = Router::new(Duration::from_millis(100));
        router.update_table(vec![("m".into(), vec![job.addr().to_string()])]);
        let resp = router.route(&regress_req()).unwrap();
        assert!(matches!(resp, Response::Regress { .. }));
        assert_eq!(count.load(Ordering::SeqCst), 1);
        assert_eq!(router.models(), vec!["m".to_string()]);
    }

    #[test]
    fn unknown_model_errors() {
        let router = Router::new(Duration::from_millis(10));
        let err = router.route(&regress_req()).unwrap_err();
        assert!(err.to_string().contains("not loaded"), "{err}");
    }

    #[test]
    fn round_robin_spreads_over_replicas() {
        let (a, ca) = counting_job();
        let (b, cb) = counting_job();
        let router = Router::new(Duration::from_millis(200));
        router.update_table(vec![(
            "m".into(),
            vec![a.addr().to_string(), b.addr().to_string()],
        )]);
        for _ in 0..10 {
            router.route(&regress_req()).unwrap();
        }
        let (na, nb) = (ca.load(Ordering::SeqCst), cb.load(Ordering::SeqCst));
        assert_eq!(na + nb, 10);
        assert!(na >= 4 && nb >= 4, "not balanced: {na}/{nb}");
    }

    #[test]
    fn admin_requests_rejected() {
        let router = Router::new(Duration::from_millis(10));
        assert!(router.route(&Request::Status).is_err());
    }

    #[test]
    fn deadline_envelope_routes_by_inner_model() {
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                // The envelope arrives intact: the replica is the one
                // that enforces the deadline.
                Request::WithDeadline { deadline_ms, inner } => {
                    assert!(deadline_ms >= 5_000);
                    match *inner {
                        Request::Regress { .. } => {
                            c.fetch_add(1, Ordering::SeqCst);
                            Response::Regress { model_version: 1, values: vec![0.0] }
                        }
                        other => panic!("unexpected inner {other:?}"),
                    }
                }
                other => panic!("expected envelope, got {other:?}"),
            }),
        )
        .unwrap();
        let router = Router::new(Duration::from_millis(100));
        router.update_table(vec![("m".into(), vec![server.addr().to_string()])]);
        let resp = router.route_with_deadline(&regress_req(), 5_000).unwrap();
        assert!(matches!(resp, Response::Regress { .. }));
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn table_update_swaps_atomically() {
        let (a, _) = counting_job();
        let router = Router::new(Duration::from_millis(100));
        router.update_table(vec![("m".into(), vec![a.addr().to_string()])]);
        assert!(router.route(&regress_req()).is_ok());
        router.update_table(vec![]); // model withdrawn
        assert!(router.route(&regress_req()).is_err());
    }
}
