//! The TFS² Router (§3.1): forwards inference RPCs to whichever serving
//! job holds the model, "using hedged backup requests to mitigate
//! latency spikes from transient server issues or inter-request or
//! -model interference".
//!
//! Two robustness layers sit between the routing table and the wire:
//!
//! * **Per-replica circuit breakers** — a replica that keeps failing
//!   transport-level is ejected (closed → open) so neither primary nor
//!   hedged attempts burn budget on it; after `open_ms` a single
//!   half-open probe decides readmission. Transitions surface as
//!   `router.breaker.*` counters.
//! * **Canary traffic splits** — during a rollout the fleet pins a
//!   deterministic fraction of *unpinned* data-plane requests to the
//!   canary version and the rest to the stable version, so health is
//!   measured under real traffic while the blast radius stays bounded.

use crate::base::error::ErrorKind;
use crate::rpc::hedged::HedgedClient;
use crate::rpc::proto::{Request, Response};
use crate::util::clock::{Clock, RealClock};
use crate::util::metrics::{Registry, WindowedCounter};
use crate::util::rcu::Rcu;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Routing table: model → replica addresses (primary rotation applied
/// per request).
type Table = HashMap<String, Vec<String>>;

/// Per-replica circuit-breaker thresholds.
#[derive(Debug, Clone)]
pub struct BreakerConfig {
    /// Open after this many consecutive failures (trips fast on a
    /// hard-dead replica regardless of rate).
    pub consecutive_failures: u32,
    /// Open when the windowed failure rate reaches this fraction …
    pub error_rate: f64,
    /// … but only once the window holds at least this many attempts
    /// (one unlucky request must not eject a replica).
    pub min_requests: u64,
    /// How long an open breaker rejects before allowing a probe.
    pub open_ms: u64,
    /// Rotation interval of the per-replica attempt/failure windows.
    pub window_ms: u64,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            consecutive_failures: 5,
            error_rate: 0.5,
            min_requests: 10,
            open_ms: 2_000,
            window_ms: 2_000,
        }
    }
}

/// Breaker state machine. `HalfOpen` tracks a probe deadline rather
/// than a boolean so a probe whose attempt never reports (lost to a
/// faster hedge) cannot wedge the breaker shut forever.
#[derive(Debug, Clone, Copy, PartialEq)]
enum BreakerState {
    Closed,
    Open { until_ns: u64 },
    HalfOpen { probe_until_ns: u64 },
}

enum Admit {
    /// Closed: route freely.
    Yes,
    /// Half-open: admit exactly this one attempt as a probe.
    Probe,
    /// Open: skip this replica.
    No,
}

struct BreakerInner {
    state: BreakerState,
    consecutive: u32,
}

/// One replica's breaker: windowed attempt/failure counts plus the
/// state machine.
struct Breaker {
    cfg: BreakerConfig,
    clock: Arc<dyn Clock>,
    requests: WindowedCounter,
    failures: WindowedCounter,
    inner: Mutex<BreakerInner>,
}

/// What a completed attempt did to the breaker (for metrics).
#[derive(Debug, PartialEq)]
enum Transition {
    None,
    Opened,
    Reopened,
    Closed,
}

impl Breaker {
    fn new(cfg: BreakerConfig, clock: Arc<dyn Clock>) -> Self {
        let window = Duration::from_millis(cfg.window_ms);
        Breaker {
            requests: WindowedCounter::new(Arc::clone(&clock), window),
            failures: WindowedCounter::new(Arc::clone(&clock), window),
            cfg,
            clock,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive: 0,
            }),
        }
    }

    /// May this replica receive the next attempt?
    fn admit(&self) -> Admit {
        let mut g = self.inner.lock().unwrap();
        let now = self.clock.now_nanos();
        match g.state {
            BreakerState::Closed => Admit::Yes,
            BreakerState::Open { until_ns } => {
                if now >= until_ns {
                    g.state = BreakerState::HalfOpen {
                        probe_until_ns: now + self.cfg.open_ms * 1_000_000,
                    };
                    Admit::Probe
                } else {
                    Admit::No
                }
            }
            BreakerState::HalfOpen { probe_until_ns } => {
                if now >= probe_until_ns {
                    // The previous probe never reported; allow another.
                    g.state = BreakerState::HalfOpen {
                        probe_until_ns: now + self.cfg.open_ms * 1_000_000,
                    };
                    Admit::Probe
                } else {
                    Admit::No
                }
            }
        }
    }

    /// Record a completed attempt (`ok` = not a replica-side failure).
    fn on_result(&self, ok: bool) -> Transition {
        self.requests.inc();
        if !ok {
            self.failures.inc();
        }
        let mut g = self.inner.lock().unwrap();
        if ok {
            g.consecutive = 0;
            if matches!(g.state, BreakerState::HalfOpen { .. }) {
                g.state = BreakerState::Closed;
                return Transition::Closed;
            }
            return Transition::None;
        }
        let until_ns = self.clock.now_nanos() + self.cfg.open_ms * 1_000_000;
        match g.state {
            BreakerState::HalfOpen { .. } => {
                // Failed probe: straight back to open.
                g.consecutive = g.consecutive.saturating_add(1);
                g.state = BreakerState::Open { until_ns };
                Transition::Reopened
            }
            BreakerState::Closed => {
                g.consecutive = g.consecutive.saturating_add(1);
                let reqs = self.requests.sum();
                let rate_tripped = reqs >= self.cfg.min_requests
                    && self.failures.sum() as f64 / reqs as f64 >= self.cfg.error_rate;
                if g.consecutive >= self.cfg.consecutive_failures || rate_tripped {
                    g.state = BreakerState::Open { until_ns };
                    Transition::Opened
                } else {
                    Transition::None
                }
            }
            BreakerState::Open { .. } => Transition::None,
        }
    }

    fn state_name(&self) -> &'static str {
        match self.inner.lock().unwrap().state {
            BreakerState::Closed => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen { .. } => "half_open",
        }
    }
}

/// An active canary traffic split for one model.
struct Split {
    stable: u64,
    canary: u64,
    /// Fraction of unpinned data-plane requests sent to the canary.
    fraction: f64,
    /// Bresenham sequence: request `n` goes canary iff
    /// `floor((n+1)·f) > floor(n·f)` — exact proportions, no RNG.
    seq: AtomicU64,
}

pub struct Router {
    /// RCU: the table is read per request, replaced by the Synchronizer.
    table: Rcu<Table>,
    hedged: HedgedClient,
    rr: AtomicUsize,
    pub registry: Arc<Registry>,
    breaker_cfg: BreakerConfig,
    clock: Arc<dyn Clock>,
    breakers: Mutex<HashMap<String, Arc<Breaker>>>,
    splits: Mutex<HashMap<String, Arc<Split>>>,
}

impl Router {
    pub fn new(hedge_delay: Duration) -> Arc<Self> {
        Self::with_config(hedge_delay, BreakerConfig::default(), RealClock::shared())
    }

    /// Full-control constructor (tests pass a [`crate::util::clock::ManualClock`]
    /// so open→half-open transitions don't need wall-clock sleeps).
    pub fn with_config(
        hedge_delay: Duration,
        breaker_cfg: BreakerConfig,
        clock: Arc<dyn Clock>,
    ) -> Arc<Self> {
        Arc::new(Router {
            table: Rcu::new(Table::new()),
            hedged: HedgedClient::new(
                Arc::new(crate::rpc::client::ClientPool::new()),
                hedge_delay,
            ),
            rr: AtomicUsize::new(0),
            registry: Registry::new(),
            breaker_cfg,
            clock,
            breakers: Mutex::new(HashMap::new()),
            splits: Mutex::new(HashMap::new()),
        })
    }

    /// Install a new routing table (from [`super::synchronizer`]).
    pub fn update_table(&self, entries: Vec<(String, Vec<String>)>) {
        self.table.update(entries.into_iter().collect());
    }

    /// Start (or retune) a canary split: `fraction` of unpinned
    /// data-plane requests for `model` pin to `canary`, the rest to
    /// `stable`. Both sides pin — otherwise unlabeled traffic would
    /// resolve `Latest` and land 100% on the canary once it loads.
    pub fn set_split(&self, model: &str, stable: u64, canary: u64, fraction: f64) {
        let fraction = fraction.clamp(0.0, 1.0);
        self.splits.lock().unwrap().insert(
            model.to_string(),
            Arc::new(Split { stable, canary, fraction, seq: AtomicU64::new(0) }),
        );
    }

    /// End a split (promotion or rollback): traffic flows unpinned
    /// again, resolving whatever the replicas now consider latest.
    pub fn clear_split(&self, model: &str) {
        self.splits.lock().unwrap().remove(model);
    }

    /// Replicas for a model, rotated so load spreads round-robin.
    fn replicas_for(&self, model: &str) -> Result<Vec<String>> {
        let guard = self.table.read();
        let replicas = guard
            .get(model)
            .filter(|r| !r.is_empty())
            .ok_or_else(|| anyhow!("model '{model}' not loaded anywhere"))?;
        let n = replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        Ok((0..n).map(|i| replicas[(start + i) % n].clone()).collect())
    }

    fn breaker_for(&self, addr: &str) -> Arc<Breaker> {
        let mut map = self.breakers.lock().unwrap();
        Arc::clone(map.entry(addr.to_string()).or_insert_with(|| {
            Arc::new(Breaker::new(self.breaker_cfg.clone(), Arc::clone(&self.clock)))
        }))
    }

    /// Current breaker state of a replica, if it has ever been routed
    /// to ("closed" / "open" / "half_open").
    pub fn breaker_state(&self, addr: &str) -> Option<&'static str> {
        self.breakers.lock().unwrap().get(addr).map(|b| b.state_name())
    }

    /// Breaker-filtered attempt order: probes first (a probe must
    /// actually reach the wire, so it rides as primary), closed
    /// replicas next, open ones skipped. All-ejected fails open to the
    /// full rotation — degraded attempts beat a guaranteed error.
    fn admit_replicas(&self, rotated: Vec<String>) -> Vec<String> {
        let mut probes = Vec::new();
        let mut closed = Vec::new();
        let mut skipped = 0u64;
        for addr in &rotated {
            match self.breaker_for(addr).admit() {
                Admit::Probe => probes.push(addr.clone()),
                Admit::Yes => closed.push(addr.clone()),
                Admit::No => skipped += 1,
            }
        }
        if skipped > 0 {
            self.registry.counter("router.breaker.skipped").add(skipped);
        }
        probes.extend(closed);
        if probes.is_empty() {
            self.registry.counter("router.breaker.failopen").inc();
            return rotated;
        }
        probes
    }

    fn observe_attempt(&self, addr: &str, result: &Result<Response>) {
        // Replica-side failure = transport error or an Internal the
        // server itself raised. Client mistakes (InvalidArgument …),
        // shedding (Unavailable), and deadline expiry never trip a
        // breaker — they say nothing about *this replica's* health.
        let ok = match result {
            Ok(_) => true,
            Err(e) => ErrorKind::of(e) != ErrorKind::Internal,
        };
        match self.breaker_for(addr).on_result(ok) {
            Transition::None => {}
            Transition::Opened => self.registry.counter("router.breaker.open").inc(),
            Transition::Reopened => self.registry.counter("router.breaker.reopen").inc(),
            Transition::Closed => self.registry.counter("router.breaker.close").inc(),
        }
    }

    /// Apply the model's canary split, if any: an *unpinned, unlabeled*
    /// data-plane request is rewritten to pin either the canary or the
    /// stable version (deadline envelope preserved). Pinned or labeled
    /// requests pass through untouched — the caller chose a side.
    fn apply_split(&self, model: &str, req: &Request) -> Option<Request> {
        let split = Arc::clone(self.splits.lock().unwrap().get(model)?);
        // Only rewrite when the innermost request is unpinned.
        let mut inner = req;
        while let Request::WithDeadline { inner: i, .. } = inner {
            inner = i;
        }
        let unpinned = match inner {
            Request::Predict { spec, .. }
            | Request::Classify { spec, .. }
            | Request::Regress { spec, .. }
            | Request::MultiInference { spec, .. } => {
                spec.version.is_none() && spec.label.is_none()
            }
            _ => false,
        };
        if !unpinned {
            return None;
        }
        let n = split.seq.fetch_add(1, Ordering::Relaxed);
        let to_canary = ((n + 1) as f64 * split.fraction).floor()
            > (n as f64 * split.fraction).floor();
        let version = if to_canary { split.canary } else { split.stable };
        self.registry
            .counter(if to_canary { "router.split.canary" } else { "router.split.stable" })
            .inc();
        Some(pin_version(req, version))
    }

    /// Route one inference request. The model name is extracted from
    /// the request; admin requests are rejected (they go through the
    /// Controller, not the data plane). A deadline envelope is looked
    /// through for extraction and forwarded whole, so the replica
    /// enforces the caller's budget.
    pub fn route(&self, req: &Request) -> Result<Response> {
        let mut inner = req;
        while let Request::WithDeadline { inner: i, .. } = inner {
            inner = i;
        }
        let model = match inner {
            Request::Predict { spec, .. }
            | Request::Classify { spec, .. }
            | Request::Regress { spec, .. }
            | Request::MultiInference { spec, .. }
            | Request::GetModelMetadata { spec } => spec.name.clone(),
            Request::Lookup { table, .. } => table.clone(),
            _ => return Err(anyhow!("router only forwards inference requests")),
        };
        let t0 = std::time::Instant::now();
        let replicas = self.admit_replicas(self.replicas_for(&model)?);
        let forwarded = self.apply_split(&model, req);
        let result = self.hedged.call_observed(
            &replicas,
            forwarded.as_ref().unwrap_or(req),
            &mut |addr, r| self.observe_attempt(addr, r),
        );
        self.registry.counter("router.requests").inc();
        if result.is_err() {
            self.registry.counter("router.errors").inc();
        }
        self.registry
            .histogram("router.latency_ns")
            .record_duration(t0.elapsed());
        result
    }

    /// Route with a deadline attached: wraps the request in the wire
    /// envelope so the replica itself enforces the caller's budget
    /// (expired work is shed there, not executed and discarded here).
    pub fn route_with_deadline(&self, req: &Request, deadline_ms: u64) -> Result<Response> {
        self.route(&req.clone().with_deadline_ms(deadline_ms))
    }

    pub fn hedge_rate(&self) -> f64 {
        self.hedged.hedge_rate()
    }

    /// Models currently routable.
    pub fn models(&self) -> Vec<String> {
        let mut m: Vec<String> = self.table.read().keys().cloned().collect();
        m.sort();
        m
    }
}

/// Rebuild `req` with its data-plane spec pinned to `version`,
/// recursing through deadline envelopes so the budget survives the
/// rewrite. Non-data-plane requests clone through unchanged.
fn pin_version(req: &Request, version: u64) -> Request {
    match req {
        Request::WithDeadline { deadline_ms, inner } => Request::WithDeadline {
            deadline_ms: *deadline_ms,
            inner: Box::new(pin_version(inner, version)),
        },
        Request::Predict { spec, signature, inputs } => Request::Predict {
            spec: pinned(spec, version),
            signature: signature.clone(),
            inputs: inputs.clone(),
        },
        Request::Classify { spec, signature, examples } => Request::Classify {
            spec: pinned(spec, version),
            signature: signature.clone(),
            examples: examples.clone(),
        },
        Request::Regress { spec, signature, examples } => Request::Regress {
            spec: pinned(spec, version),
            signature: signature.clone(),
            examples: examples.clone(),
        },
        Request::MultiInference { spec, tasks, examples } => Request::MultiInference {
            spec: pinned(spec, version),
            tasks: tasks.clone(),
            examples: examples.clone(),
        },
        other => other.clone(),
    }
}

fn pinned(spec: &crate::inference::ModelSpec, version: u64) -> crate::inference::ModelSpec {
    crate::inference::ModelSpec {
        name: spec.name.clone(),
        version: Some(version),
        label: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use crate::util::clock::ManualClock;
    use std::sync::atomic::{AtomicBool, AtomicU64};

    fn counting_job() -> (Arc<RpcServer>, Arc<AtomicU64>) {
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                Request::Regress { .. } => {
                    c.fetch_add(1, Ordering::SeqCst);
                    Response::Regress { model_version: 1, values: vec![0.0] }
                }
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap();
        (server, count)
    }

    fn regress_req() -> Request {
        Request::regress("m", None, vec![crate::inference::example::Example::new()])
    }

    #[test]
    fn routes_to_loaded_job() {
        let (job, count) = counting_job();
        let router = Router::new(Duration::from_millis(100));
        router.update_table(vec![("m".into(), vec![job.addr().to_string()])]);
        let resp = router.route(&regress_req()).unwrap();
        assert!(matches!(resp, Response::Regress { .. }));
        assert_eq!(count.load(Ordering::SeqCst), 1);
        assert_eq!(router.models(), vec!["m".to_string()]);
    }

    #[test]
    fn unknown_model_errors() {
        let router = Router::new(Duration::from_millis(10));
        let err = router.route(&regress_req()).unwrap_err();
        assert!(err.to_string().contains("not loaded"), "{err}");
    }

    #[test]
    fn round_robin_spreads_over_replicas() {
        let (a, ca) = counting_job();
        let (b, cb) = counting_job();
        let router = Router::new(Duration::from_millis(200));
        router.update_table(vec![(
            "m".into(),
            vec![a.addr().to_string(), b.addr().to_string()],
        )]);
        for _ in 0..10 {
            router.route(&regress_req()).unwrap();
        }
        let (na, nb) = (ca.load(Ordering::SeqCst), cb.load(Ordering::SeqCst));
        assert_eq!(na + nb, 10);
        assert!(na >= 4 && nb >= 4, "not balanced: {na}/{nb}");
    }

    #[test]
    fn admin_requests_rejected() {
        let router = Router::new(Duration::from_millis(10));
        assert!(router.route(&Request::Status).is_err());
    }

    #[test]
    fn deadline_envelope_routes_by_inner_model() {
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                // The envelope arrives intact: the replica is the one
                // that enforces the deadline.
                Request::WithDeadline { deadline_ms, inner } => {
                    assert!(deadline_ms >= 5_000);
                    match *inner {
                        Request::Regress { .. } => {
                            c.fetch_add(1, Ordering::SeqCst);
                            Response::Regress { model_version: 1, values: vec![0.0] }
                        }
                        other => panic!("unexpected inner {other:?}"),
                    }
                }
                other => panic!("expected envelope, got {other:?}"),
            }),
        )
        .unwrap();
        let router = Router::new(Duration::from_millis(100));
        router.update_table(vec![("m".into(), vec![server.addr().to_string()])]);
        let resp = router.route_with_deadline(&regress_req(), 5_000).unwrap();
        assert!(matches!(resp, Response::Regress { .. }));
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn table_update_swaps_atomically() {
        let (a, _) = counting_job();
        let router = Router::new(Duration::from_millis(100));
        router.update_table(vec![("m".into(), vec![a.addr().to_string()])]);
        assert!(router.route(&regress_req()).is_ok());
        router.update_table(vec![]); // model withdrawn
        assert!(router.route(&regress_req()).is_err());
    }

    // ---- breaker state machine (no sockets) ----

    #[test]
    fn breaker_trips_on_consecutive_failures_then_recovers() {
        let clock = Arc::new(ManualClock::new());
        let cfg = BreakerConfig { consecutive_failures: 3, ..Default::default() };
        let b = Breaker::new(cfg, clock.clone());
        assert!(matches!(b.admit(), Admit::Yes));
        assert_eq!(b.on_result(false), Transition::None);
        assert_eq!(b.on_result(false), Transition::None);
        assert_eq!(b.on_result(false), Transition::Opened);
        assert!(matches!(b.admit(), Admit::No));
        // Still open before the cooldown elapses.
        clock.advance(Duration::from_millis(1_999));
        assert!(matches!(b.admit(), Admit::No));
        clock.advance(Duration::from_millis(1));
        // Half-open: exactly one probe admitted.
        assert!(matches!(b.admit(), Admit::Probe));
        assert!(matches!(b.admit(), Admit::No));
        assert_eq!(b.on_result(true), Transition::Closed);
        assert!(matches!(b.admit(), Admit::Yes));
    }

    #[test]
    fn breaker_trips_on_windowed_error_rate() {
        let clock = Arc::new(ManualClock::new());
        // Rate gate only: consecutive threshold out of reach.
        let cfg = BreakerConfig {
            consecutive_failures: u32::MAX,
            error_rate: 0.5,
            min_requests: 10,
            ..Default::default()
        };
        let b = Breaker::new(cfg, clock.clone());
        // Alternate ok/fail: rate 0.5, trips once min_requests hit.
        let mut opened = false;
        for i in 0..10 {
            let t = b.on_result(i % 2 == 0);
            opened |= t == Transition::Opened;
        }
        assert!(opened, "breaker should trip at 50% failure over >=10 attempts");
        // A failed probe goes straight back to open.
        clock.advance(Duration::from_millis(2_000));
        assert!(matches!(b.admit(), Admit::Probe));
        assert_eq!(b.on_result(false), Transition::Reopened);
        assert!(matches!(b.admit(), Admit::No));
    }

    #[test]
    fn breaker_rate_gate_forgets_old_windows() {
        let clock = Arc::new(ManualClock::new());
        let cfg = BreakerConfig {
            consecutive_failures: u32::MAX,
            error_rate: 0.5,
            min_requests: 10,
            window_ms: 1_000,
            ..Default::default()
        };
        let b = Breaker::new(cfg, clock.clone());
        // 9 failures — under min_requests, stays closed.
        for _ in 0..9 {
            assert_eq!(b.on_result(false), Transition::None);
        }
        // Rotate far past both buckets: old failures age out.
        clock.advance(Duration::from_secs(10));
        // Healthy traffic plus one failure: rate 1/10 < 0.5.
        for _ in 0..9 {
            b.on_result(true);
        }
        assert_eq!(b.on_result(false), Transition::None);
        assert!(matches!(b.admit(), Admit::Yes));
    }

    // ---- breaker wired into routing (real sockets) ----

    /// Server whose handler fails with Internal while `fail` is set.
    fn flaky_job(fail: Arc<AtomicBool>) -> (Arc<RpcServer>, Arc<AtomicU64>) {
        let count = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&count);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| {
                c.fetch_add(1, Ordering::SeqCst);
                if fail.load(Ordering::SeqCst) {
                    return Response::Error {
                        kind: crate::base::error::ErrorKind::Internal,
                        message: "injected".into(),
                    };
                }
                match req {
                    Request::Regress { .. } | Request::WithDeadline { .. } => {
                        Response::Regress { model_version: 1, values: vec![0.0] }
                    }
                    _ => Response::Error {
                        kind: crate::base::error::ErrorKind::Internal,
                        message: "no".into(),
                    },
                }
            }),
        )
        .unwrap();
        (server, count)
    }

    #[test]
    fn routing_ejects_failing_replica_then_readmits() {
        let clock = Arc::new(ManualClock::new());
        let fail = Arc::new(AtomicBool::new(true));
        let (bad, bad_count) = flaky_job(Arc::clone(&fail));
        let (good, _good_count) = counting_job();
        let cfg = BreakerConfig { consecutive_failures: 3, ..Default::default() };
        let router = Router::with_config(Duration::from_millis(200), cfg, clock.clone());
        router.update_table(vec![(
            "m".into(),
            vec![bad.addr().to_string(), good.addr().to_string()],
        )]);
        // Every request succeeds (failover covers the bad replica);
        // after 3 completed failures the bad replica's breaker opens.
        for _ in 0..8 {
            router.route(&regress_req()).unwrap();
        }
        assert_eq!(router.breaker_state(&bad.addr().to_string()), Some("open"));
        let open = router.registry.counter("router.breaker.open").get();
        assert!(open >= 1, "open transitions: {open}");
        // While open, the bad replica receives no traffic at all.
        let before = bad_count.load(Ordering::SeqCst);
        for _ in 0..10 {
            router.route(&regress_req()).unwrap();
        }
        assert_eq!(bad_count.load(Ordering::SeqCst), before, "ejected replica was routed to");
        assert!(router.registry.counter("router.breaker.skipped").get() >= 10);
        // Heal the replica, expire the cooldown: one probe readmits it.
        fail.store(false, Ordering::SeqCst);
        clock.advance(Duration::from_millis(2_000));
        for _ in 0..4 {
            router.route(&regress_req()).unwrap();
        }
        assert_eq!(router.breaker_state(&bad.addr().to_string()), Some("closed"));
        assert!(router.registry.counter("router.breaker.close").get() >= 1);
        assert!(bad_count.load(Ordering::SeqCst) > before, "healed replica still ejected");
    }

    // ---- canary splits ----

    /// Job that tallies which pinned version each regress carries.
    fn version_tally_job() -> (Arc<RpcServer>, Arc<Mutex<HashMap<Option<u64>, u64>>>) {
        let tally: Arc<Mutex<HashMap<Option<u64>, u64>>> = Arc::new(Mutex::new(HashMap::new()));
        let t = Arc::clone(&tally);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| {
                let mut r = &req;
                while let Request::WithDeadline { inner, .. } = r {
                    r = inner;
                }
                match r {
                    Request::Regress { spec, .. } => {
                        *t.lock().unwrap().entry(spec.version).or_insert(0) += 1;
                        Response::Regress {
                            model_version: spec.version.unwrap_or(9),
                            values: vec![0.0],
                        }
                    }
                    _ => Response::Error {
                        kind: crate::base::error::ErrorKind::Internal,
                        message: "no".into(),
                    },
                }
            }),
        )
        .unwrap();
        (server, tally)
    }

    #[test]
    fn split_pins_exact_canary_fraction() {
        let (job, tally) = version_tally_job();
        let router = Router::new(Duration::from_millis(200));
        router.update_table(vec![("m".into(), vec![job.addr().to_string()])]);
        router.set_split("m", 1, 2, 0.25);
        for _ in 0..40 {
            router.route(&regress_req()).unwrap();
        }
        let t = tally.lock().unwrap().clone();
        // Bresenham: exactly 25% canary, 75% stable, nothing unpinned.
        assert_eq!(t.get(&Some(2)), Some(&10), "{t:?}");
        assert_eq!(t.get(&Some(1)), Some(&30), "{t:?}");
        assert_eq!(t.get(&None), None, "{t:?}");
        assert_eq!(router.registry.counter("router.split.canary").get(), 10);
        assert_eq!(router.registry.counter("router.split.stable").get(), 30);
        // Clearing the split stops the rewrite.
        router.clear_split("m");
        router.route(&regress_req()).unwrap();
        assert_eq!(*tally.lock().unwrap().get(&None).unwrap(), 1);
    }

    #[test]
    fn split_leaves_pinned_and_labeled_requests_alone() {
        let (job, tally) = version_tally_job();
        let router = Router::new(Duration::from_millis(200));
        router.update_table(vec![("m".into(), vec![job.addr().to_string()])]);
        router.set_split("m", 1, 2, 1.0); // everything unpinned → canary
        // An explicitly pinned request keeps its version.
        let pinned_req = Request::Regress {
            spec: crate::inference::ModelSpec {
                name: "m".into(),
                version: Some(7),
                label: None,
            },
            signature: String::new(),
            examples: vec![crate::inference::example::Example::new()],
        };
        router.route(&pinned_req).unwrap();
        assert_eq!(*tally.lock().unwrap().get(&Some(7)).unwrap(), 1);
        // The deadline envelope survives the rewrite (the tally job
        // unwraps it and sees the pinned canary version).
        router.route_with_deadline(&regress_req(), 5_000).unwrap();
        assert_eq!(*tally.lock().unwrap().get(&Some(2)).unwrap(), 1);
    }
}
