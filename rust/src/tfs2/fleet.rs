//! One handle over the whole TFS² control plane (paper Figure 2,
//! assembled): a [`Controller`] backed by the durable [`Store`], an
//! in-process [`Cluster`] of real serving jobs, the [`Synchronizer`]
//! pushing versions/labels and scraping load, a metric-driven
//! [`Autoscaler`], and a hedged [`Router`] for the data plane.
//!
//! The loop a deployment runs:
//!
//! ```text
//! deploy/label (Controller, durable)
//!        │
//! reconcile(): desired_state ─► Synchronizer ─► replicas
//!        │                          │
//!        │                    routing table ─► Router
//!        │
//! autoscale_once(): scrape_load ─► Autoscaler ─► Cluster.scale_to
//!                                        └─► reconcile() again
//!
//! rollout_once(): scrape_health ─► RolloutEngine ─► split / promote /
//!                                        auto-rollback + status push
//! ```

use super::autoscaler::{Autoscaler, AutoscalerConfig, Decision, LoadSignal};
use super::cluster::Cluster;
use super::controller::Controller;
use super::rollout::{RolloutAction, RolloutEngine, RolloutPolicy, RolloutState};
use super::router::{BreakerConfig, Router};
use super::store::Store;
use super::synchronizer::{SyncReport, Synchronizer};
use crate::rpc::client::ClientPool;
use crate::rpc::proto::{Request, Response};
use crate::util::clock::{Clock, RealClock};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct FleetConfig {
    /// Serving jobs to start.
    pub jobs: usize,
    /// RAM capacity per job (placement budget, not an OS limit).
    pub capacity_bytes: u64,
    /// Shared artifact root every job loads from.
    pub artifacts_root: PathBuf,
    pub autoscaler: AutoscalerConfig,
    /// Hedged-routing backup delay (PR 6 machinery).
    pub hedge_delay: Duration,
    /// Replica circuit-breaker thresholds for the Router.
    pub breaker: BreakerConfig,
    /// Clock driving breaker open→half-open transitions and rollout
    /// bake timing (tests inject a [`crate::util::clock::ManualClock`]).
    pub clock: Arc<dyn Clock>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            jobs: 2,
            capacity_bytes: 1 << 30,
            artifacts_root: std::env::temp_dir(),
            autoscaler: AutoscalerConfig::default(),
            hedge_delay: Duration::from_millis(50),
            breaker: BreakerConfig::default(),
            clock: RealClock::shared(),
        }
    }
}

pub struct Fleet {
    pub controller: Controller,
    pub cluster: Cluster,
    pub synchronizer: Synchronizer,
    pub router: Arc<Router>,
    pub rollouts: RolloutEngine,
    autoscaler: Mutex<Autoscaler>,
    pool: Arc<ClientPool>,
}

impl Fleet {
    /// Start the serving jobs and wire the control plane over `store`
    /// (pass a disk-backed store for durability across restarts).
    pub fn start(store: Arc<Store>, config: FleetConfig) -> Result<Fleet> {
        let cluster =
            Cluster::start(config.jobs, config.capacity_bytes, config.artifacts_root.clone())?;
        let controller = Controller::new(Arc::clone(&store));
        let mut autoscaler = Autoscaler::new(config.autoscaler);
        for (job, addr, capacity) in cluster.jobs() {
            controller.register_job(&job, &addr, capacity)?;
            controller.set_job_replicas(&job, &cluster.replica_addrs(&job))?;
            autoscaler.track(&job, cluster.replica_addrs(&job).len());
        }
        let pool = Arc::new(ClientPool::new());
        let synchronizer = Synchronizer::new(store, Arc::clone(&pool));
        Ok(Fleet {
            controller,
            cluster,
            synchronizer,
            router: Router::with_config(
                config.hedge_delay,
                config.breaker,
                Arc::clone(&config.clock),
            ),
            rollouts: RolloutEngine::new(config.clock),
            autoscaler: Mutex::new(autoscaler),
            pool,
        })
    }

    /// Place a model (best-fit by RAM) and desire its first version.
    /// Returns the chosen job. Call [`Fleet::reconcile`] to make the
    /// replicas actually load it.
    pub fn deploy(
        &self,
        name: &str,
        base_path: &str,
        ram_bytes: u64,
        version: u64,
    ) -> Result<String> {
        self.controller.add_model(name, base_path, ram_bytes, version)
    }

    /// One control-plane pass: record live replica addresses, push
    /// desired versions and labels everywhere, refresh the Router's
    /// table from what actually loaded.
    pub fn reconcile(&self) -> Result<SyncReport> {
        for (job, _, _) in self.cluster.jobs() {
            self.controller
                .set_job_replicas(&job, &self.cluster.replica_addrs(&job))?;
        }
        let report = self.synchronizer.sync_once(&self.controller.desired_state())?;
        self.router.update_table(self.synchronizer.routing_table());
        Ok(report)
    }

    /// One autoscaling pass: scrape real load signals from every
    /// replica, let the Autoscaler decide, apply the decisions to the
    /// cluster, and reconcile so new replicas pick up their models.
    pub fn autoscale_once(&self) -> Result<Vec<Decision>> {
        let desired = self.controller.desired_state();
        let signals: HashMap<String, LoadSignal> = self
            .synchronizer
            .scrape_load(&desired)
            .into_iter()
            .map(|(job, load)| {
                (
                    job,
                    LoadSignal {
                        lane_depth: load.lane_depth,
                        // The *windowed* p99 drives scaling: the
                        // cumulative series (kept for /metrics) never
                        // forgets a startup spike, so a job that
                        // recovered an hour ago would stay scaled up
                        // forever on the lifetime percentile.
                        queue_delay_p99_ns: load.queue_delay_window_p99_ns,
                        shed_delta: load.shed_delta,
                    },
                )
            })
            .collect();
        let decisions = self.autoscaler.lock().unwrap().tick_signals(&signals);
        for d in &decisions {
            crate::log_info!("autoscale: {} {} -> {} replicas", d.job, d.from, d.to);
            self.cluster.scale_to(&d.job, d.to)?;
        }
        if !decisions.is_empty() {
            self.reconcile()?;
        }
        Ok(decisions)
    }

    /// Durable label attach + immediate fan-out to the replicas.
    pub fn set_label(&self, model: &str, label: &str, version: u64) -> Result<()> {
        self.controller.set_version_label(model, label, version)?;
        self.reconcile()?;
        Ok(())
    }

    /// Begin a health-gated rollout of `version`: canary mode on, the
    /// new version loads alongside the current primary (which becomes
    /// the `stable` side), and the [`RolloutEngine`] takes over — call
    /// [`Fleet::rollout_once`] each control-plane tick to ramp,
    /// promote, or auto-rollback. No traffic reaches the canary until
    /// it is ready on every replica.
    pub fn start_rollout(
        &self,
        model: &str,
        version: u64,
        policy: RolloutPolicy,
    ) -> Result<()> {
        let stable = self
            .controller
            .desired_versions(model)?
            .into_iter()
            .max()
            .ok_or_else(|| anyhow!("model '{model}' has no serving version to canary against"))?;
        if stable == version {
            return Err(anyhow!("version {version} is already the primary of '{model}'"));
        }
        self.controller.set_canary(model, true)?;
        self.controller.add_version(model, version)?;
        self.controller.set_version_label(model, "stable", stable)?;
        self.controller.set_version_label(model, "canary", version)?;
        // Pin all unpinned traffic to stable until the canary is ready
        // and the engine opens the first ramp step — otherwise Latest
        // would resolve to the canary the moment it loads.
        self.router.set_split(model, stable, version, 0.0);
        self.rollouts.begin(model, stable, version, policy);
        self.reconcile()?;
        self.push_rollout_status(model);
        Ok(())
    }

    /// One rollout evaluation pass over every in-flight rollout: scrape
    /// windowed health, let the engine decide, apply its actions
    /// (traffic splits via the Router, promote/rollback via the
    /// Controller), and push the human-readable status to the replicas
    /// so `GET /v1/models` shows it. Returns the actions applied,
    /// keyed by model.
    pub fn rollout_once(&self) -> Result<Vec<(String, RolloutAction)>> {
        let desired = self.controller.desired_state();
        let health = self.synchronizer.scrape_health(&desired);
        let mut applied = Vec::new();
        let mut need_reconcile = false;
        for model in self.rollouts.in_flight() {
            let Some(state) = self.rollouts.state(&model) else { continue };
            // No traffic before the canary version reports ready on
            // EVERY replica of the placed job (polled explicitly — the
            // routing table can't answer per-version questions).
            let expected: Vec<String> = desired
                .iter()
                .find(|j| j.models.iter().any(|m| m.name == model))
                .map(|j| j.replicas.clone())
                .unwrap_or_default();
            let canary_ready = !expected.is_empty()
                && expected.iter().filter(|a| !a.is_empty()).all(|addr| {
                    matches!(
                        self.pool.call(addr, &Request::ModelStatus { model: model.clone() }),
                        Ok(Response::ModelStatus { versions })
                            if versions.iter().any(|(v, st)| *v == state.canary && st == "ready")
                    )
                });
            let canary_h = health
                .get(&(model.clone(), state.canary))
                .copied()
                .unwrap_or_default();
            let stable_h = health
                .get(&(model.clone(), state.stable))
                .copied()
                .unwrap_or_default();
            for action in self.rollouts.tick(&model, canary_ready, &canary_h, &stable_h) {
                self.apply_rollout_action(&model, &state, &action, &mut need_reconcile)?;
                applied.push((model.clone(), action));
            }
            self.push_rollout_status(&model);
        }
        if need_reconcile {
            self.reconcile()?;
        }
        Ok(applied)
    }

    fn apply_rollout_action(
        &self,
        model: &str,
        state: &RolloutState,
        action: &RolloutAction,
        need_reconcile: &mut bool,
    ) -> Result<()> {
        match action {
            RolloutAction::SetSplit { fraction } => {
                crate::log_info!(
                    "rollout: {model} canary v{} at {:.0}%",
                    state.canary,
                    fraction * 100.0
                );
                self.router.set_split(model, state.stable, state.canary, *fraction);
            }
            RolloutAction::Promote => {
                crate::log_info!("rollout: {model} promoting v{}", state.canary);
                // Move the stable label onto the canary while BOTH
                // versions are still desired and loaded, and fan it
                // out, so no stable-label request can land in the gap
                // between the old primary unloading and the label
                // moving. Only then shrink the desired set.
                self.controller.set_version_label(model, "stable", state.canary)?;
                self.reconcile()?;
                self.controller.promote_canary(model)?;
                let _ = self.controller.delete_version_label(model, "canary");
                self.controller.set_canary(model, false)?;
                self.router.clear_split(model);
                *need_reconcile = true;
            }
            RolloutAction::Rollback { reason } => {
                crate::log_warn!("rollout: {model} auto-rollback: {reason}");
                // Pin everything to stable *before* the desired-set
                // change: the canary stays transiently servable on the
                // replicas until reconcile unloads it, and unpinned
                // Latest would resolve to it in that window. The pin is
                // harmless afterwards (stable is the only version) and
                // the next rollout's split replaces it.
                self.router.set_split(model, state.stable, state.stable, 0.0);
                self.controller.rollback(model, state.stable)?;
                self.controller.set_canary(model, false)?;
                *need_reconcile = true;
            }
        }
        Ok(())
    }

    /// Current rollout status line for a model ("ramping: …",
    /// "rolled_back: …"), if a rollout was ever begun.
    pub fn rollout_status(&self, model: &str) -> Option<String> {
        self.rollouts.status_line(model)
    }

    /// Best-effort push of the status line to every replica serving the
    /// model, so data-plane `GET /v1/models` surfaces it. Failures are
    /// ignored — the next tick retries.
    fn push_rollout_status(&self, model: &str) {
        let Some(status) = self.rollouts.status_line(model) else { return };
        let Some(job) = self.controller.placement(model) else { return };
        for addr in self.cluster.replica_addrs(&job) {
            let req = Request::SetRolloutStatus {
                model: model.to_string(),
                status: status.clone(),
            };
            if let Err(e) = self.pool.call(&addr, &req) {
                crate::log_warn!("rollout: status push to {addr} failed: {e}");
            }
        }
    }

    pub fn stop(&self) {
        self.cluster.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_starts_registers_and_reconciles_empty() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 2, ..Default::default() },
        )
        .unwrap();
        // Jobs registered with the controller, replicas recorded.
        let desired = fleet.controller.desired_state();
        assert_eq!(desired.len(), 2);
        assert!(desired.iter().all(|j| j.replicas.len() == 1));
        // Nothing deployed: reconcile is a clean no-op.
        let report = fleet.reconcile().unwrap();
        assert_eq!(report.instructed, 0);
        assert_eq!(report.ready, 0);
        assert!(report.unreachable.is_empty());
        assert!(fleet.router.models().is_empty());
        fleet.stop();
    }

    #[test]
    fn idle_fleet_makes_no_scaling_decisions() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 1, ..Default::default() },
        )
        .unwrap();
        assert!(fleet.autoscale_once().unwrap().is_empty());
        assert_eq!(fleet.cluster.replica_addrs("job-0").len(), 1);
        fleet.stop();
    }

    #[test]
    fn rollout_requires_a_serving_primary() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 1, ..Default::default() },
        )
        .unwrap();
        // Unknown model: the controller refuses.
        assert!(fleet.start_rollout("ghost", 2, RolloutPolicy::default()).is_err());
        assert_eq!(fleet.rollout_status("ghost"), None);
        // Same version as the primary: nothing to canary.
        fleet.deploy("m", "/m", 1, 1).unwrap();
        let err = fleet.start_rollout("m", 1, RolloutPolicy::default()).unwrap_err();
        assert!(err.to_string().contains("already the primary"), "{err}");
        // No in-flight rollouts: the evaluation pass is a clean no-op.
        assert!(fleet.rollout_once().unwrap().is_empty());
        fleet.stop();
    }

    #[test]
    fn deploy_respects_capacity() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 1, capacity_bytes: 100, ..Default::default() },
        )
        .unwrap();
        let err = fleet.deploy("huge", "/m", 1 << 20, 1).unwrap_err();
        assert!(err.to_string().contains("free"), "{err}");
        fleet.stop();
    }
}
