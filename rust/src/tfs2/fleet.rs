//! One handle over the whole TFS² control plane (paper Figure 2,
//! assembled): a [`Controller`] backed by the durable [`Store`], an
//! in-process [`Cluster`] of real serving jobs, the [`Synchronizer`]
//! pushing versions/labels and scraping load, a metric-driven
//! [`Autoscaler`], and a hedged [`Router`] for the data plane.
//!
//! The loop a deployment runs:
//!
//! ```text
//! deploy/label (Controller, durable)
//!        │
//! reconcile(): desired_state ─► Synchronizer ─► replicas
//!        │                          │
//!        │                    routing table ─► Router
//!        │
//! autoscale_once(): scrape_load ─► Autoscaler ─► Cluster.scale_to
//!                                        └─► reconcile() again
//! ```

use super::autoscaler::{Autoscaler, AutoscalerConfig, Decision, LoadSignal};
use super::cluster::Cluster;
use super::controller::Controller;
use super::router::Router;
use super::store::Store;
use super::synchronizer::{SyncReport, Synchronizer};
use crate::rpc::client::ClientPool;
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct FleetConfig {
    /// Serving jobs to start.
    pub jobs: usize,
    /// RAM capacity per job (placement budget, not an OS limit).
    pub capacity_bytes: u64,
    /// Shared artifact root every job loads from.
    pub artifacts_root: PathBuf,
    pub autoscaler: AutoscalerConfig,
    /// Hedged-routing backup delay (PR 6 machinery).
    pub hedge_delay: Duration,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            jobs: 2,
            capacity_bytes: 1 << 30,
            artifacts_root: std::env::temp_dir(),
            autoscaler: AutoscalerConfig::default(),
            hedge_delay: Duration::from_millis(50),
        }
    }
}

pub struct Fleet {
    pub controller: Controller,
    pub cluster: Cluster,
    pub synchronizer: Synchronizer,
    pub router: Arc<Router>,
    autoscaler: Mutex<Autoscaler>,
}

impl Fleet {
    /// Start the serving jobs and wire the control plane over `store`
    /// (pass a disk-backed store for durability across restarts).
    pub fn start(store: Arc<Store>, config: FleetConfig) -> Result<Fleet> {
        let cluster =
            Cluster::start(config.jobs, config.capacity_bytes, config.artifacts_root.clone())?;
        let controller = Controller::new(Arc::clone(&store));
        let mut autoscaler = Autoscaler::new(config.autoscaler);
        for (job, addr, capacity) in cluster.jobs() {
            controller.register_job(&job, &addr, capacity)?;
            controller.set_job_replicas(&job, &cluster.replica_addrs(&job))?;
            autoscaler.track(&job, cluster.replica_addrs(&job).len());
        }
        let synchronizer = Synchronizer::new(store, Arc::new(ClientPool::new()));
        Ok(Fleet {
            controller,
            cluster,
            synchronizer,
            router: Router::new(config.hedge_delay),
            autoscaler: Mutex::new(autoscaler),
        })
    }

    /// Place a model (best-fit by RAM) and desire its first version.
    /// Returns the chosen job. Call [`Fleet::reconcile`] to make the
    /// replicas actually load it.
    pub fn deploy(
        &self,
        name: &str,
        base_path: &str,
        ram_bytes: u64,
        version: u64,
    ) -> Result<String> {
        self.controller.add_model(name, base_path, ram_bytes, version)
    }

    /// One control-plane pass: record live replica addresses, push
    /// desired versions and labels everywhere, refresh the Router's
    /// table from what actually loaded.
    pub fn reconcile(&self) -> Result<SyncReport> {
        for (job, _, _) in self.cluster.jobs() {
            self.controller
                .set_job_replicas(&job, &self.cluster.replica_addrs(&job))?;
        }
        let report = self.synchronizer.sync_once(&self.controller.desired_state())?;
        self.router.update_table(self.synchronizer.routing_table());
        Ok(report)
    }

    /// One autoscaling pass: scrape real load signals from every
    /// replica, let the Autoscaler decide, apply the decisions to the
    /// cluster, and reconcile so new replicas pick up their models.
    pub fn autoscale_once(&self) -> Result<Vec<Decision>> {
        let desired = self.controller.desired_state();
        let signals: HashMap<String, LoadSignal> = self
            .synchronizer
            .scrape_load(&desired)
            .into_iter()
            .map(|(job, load)| {
                (
                    job,
                    LoadSignal {
                        lane_depth: load.lane_depth,
                        queue_delay_p99_ns: load.queue_delay_p99_ns,
                        shed_delta: load.shed_delta,
                    },
                )
            })
            .collect();
        let decisions = self.autoscaler.lock().unwrap().tick_signals(&signals);
        for d in &decisions {
            crate::log_info!("autoscale: {} {} -> {} replicas", d.job, d.from, d.to);
            self.cluster.scale_to(&d.job, d.to)?;
        }
        if !decisions.is_empty() {
            self.reconcile()?;
        }
        Ok(decisions)
    }

    /// Durable label attach + immediate fan-out to the replicas.
    pub fn set_label(&self, model: &str, label: &str, version: u64) -> Result<()> {
        self.controller.set_version_label(model, label, version)?;
        self.reconcile()?;
        Ok(())
    }

    pub fn stop(&self) {
        self.cluster.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_starts_registers_and_reconciles_empty() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 2, ..Default::default() },
        )
        .unwrap();
        // Jobs registered with the controller, replicas recorded.
        let desired = fleet.controller.desired_state();
        assert_eq!(desired.len(), 2);
        assert!(desired.iter().all(|j| j.replicas.len() == 1));
        // Nothing deployed: reconcile is a clean no-op.
        let report = fleet.reconcile().unwrap();
        assert_eq!(report.instructed, 0);
        assert_eq!(report.ready, 0);
        assert!(report.unreachable.is_empty());
        assert!(fleet.router.models().is_empty());
        fleet.stop();
    }

    #[test]
    fn idle_fleet_makes_no_scaling_decisions() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 1, ..Default::default() },
        )
        .unwrap();
        assert!(fleet.autoscale_once().unwrap().is_empty());
        assert_eq!(fleet.cluster.replica_addrs("job-0").len(), 1);
        fleet.stop();
    }

    #[test]
    fn deploy_respects_capacity() {
        let fleet = Fleet::start(
            Store::in_memory(0),
            FleetConfig { jobs: 1, capacity_bytes: 100, ..Default::default() },
        )
        .unwrap();
        let err = fleet.deploy("huge", "/m", 1 << 20, 1).unwrap_err();
        assert!(err.to_string().contains("free"), "{err}");
        fleet.stop();
    }
}
