//! The Controller's state store — our Spanner stand-in (§3.1: "The
//! Controller keeps all its state in Spanner, a globally-replicated
//! database system, and manages it transactionally").
//!
//! What the Controller actually needs from Spanner: durable,
//! transactional (serializable) metadata with replicated reads. We
//! provide exactly that, scaled to one process:
//!
//! * **Serializable transactions** — writers run one at a time under a
//!   commit lock over a `BTreeMap<String, Json>`, with buffered writes
//!   applied atomically.
//! * **Durability** — a write-ahead log (JSON lines) fsynced per commit
//!   plus snapshot compaction; `open` recovers snapshot + WAL replay,
//!   dropping a torn final record (crash mid-append) by truncating the
//!   WAL back to its valid prefix.
//! * **Replication (simulated)** — N follower maps apply the log
//!   asynchronously; follower reads can be stale until `tick` runs,
//!   modelling cross-DC lag for the Synchronizer tests.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

type Map = BTreeMap<String, Json>;

/// One committed mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Put(String, Json),
    Delete(String),
}

impl Op {
    fn to_json(&self) -> Json {
        match self {
            Op::Put(k, v) => Json::obj(vec![("put", Json::str(k.clone())), ("v", v.clone())]),
            Op::Delete(k) => Json::obj(vec![("del", Json::str(k.clone()))]),
        }
    }

    fn from_json(j: &Json) -> Result<Op> {
        if let Some(k) = j.get("put").and_then(|v| v.as_str()) {
            Ok(Op::Put(
                k.to_string(),
                j.get("v").cloned().ok_or_else(|| anyhow!("put without value"))?,
            ))
        } else if let Some(k) = j.get("del").and_then(|v| v.as_str()) {
            Ok(Op::Delete(k.to_string()))
        } else {
            Err(anyhow!("bad wal op: {j}"))
        }
    }

    fn apply(&self, map: &mut Map) {
        match self {
            Op::Put(k, v) => {
                map.insert(k.clone(), v.clone());
            }
            Op::Delete(k) => {
                map.remove(k);
            }
        }
    }
}

struct Follower {
    map: Map,
    /// Log index this follower has applied up to.
    applied: usize,
}

struct Inner {
    leader: Map,
    /// Committed ops since the snapshot (the in-memory tail of the WAL).
    log: Vec<Op>,
    followers: Vec<Follower>,
    commits: u64,
}

/// The store handle (leader).
pub struct Store {
    inner: Mutex<Inner>,
    /// Commit lock: one transaction at a time = serializable.
    commit: Mutex<()>,
    wal_path: Option<PathBuf>,
    wal: Mutex<Option<std::fs::File>>,
}

/// Buffered transaction view.
pub struct Txn<'a> {
    base: &'a Map,
    writes: Vec<Op>,
}

impl<'a> Txn<'a> {
    pub fn get(&self, key: &str) -> Option<Json> {
        // Read-your-writes within the txn.
        for op in self.writes.iter().rev() {
            match op {
                Op::Put(k, v) if k == key => return Some(v.clone()),
                Op::Delete(k) if k == key => return None,
                _ => {}
            }
        }
        self.base.get(key).cloned()
    }

    pub fn put(&mut self, key: &str, value: Json) {
        self.writes.push(Op::Put(key.to_string(), value));
    }

    pub fn delete(&mut self, key: &str) {
        self.writes.push(Op::Delete(key.to_string()));
    }

    pub fn scan_prefix(&self, prefix: &str) -> Vec<(String, Json)> {
        let mut out: BTreeMap<String, Json> = self
            .base
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        for op in &self.writes {
            match op {
                Op::Put(k, v) if k.starts_with(prefix) => {
                    out.insert(k.clone(), v.clone());
                }
                Op::Delete(k) => {
                    out.remove(k);
                }
                _ => {}
            }
        }
        out.into_iter().collect()
    }
}

impl Store {
    /// In-memory store with `followers` simulated replicas.
    pub fn in_memory(followers: usize) -> Arc<Store> {
        Arc::new(Store {
            inner: Mutex::new(Inner {
                leader: Map::new(),
                log: Vec::new(),
                followers: (0..followers)
                    .map(|_| Follower { map: Map::new(), applied: 0 })
                    .collect(),
                commits: 0,
            }),
            commit: Mutex::new(()),
            wal_path: None,
            wal: Mutex::new(None),
        })
    }

    /// Durable store: recovers `<path>.snap` + `<path>.wal` if present.
    pub fn open(path: &PathBuf, followers: usize) -> Result<Arc<Store>> {
        let snap_path = path.with_extension("snap");
        let wal_path = path.with_extension("wal");
        let mut leader = Map::new();
        if snap_path.exists() {
            let json = Json::parse_file(&snap_path).context("reading snapshot")?;
            if let Some(obj) = json.as_obj() {
                leader = obj.clone();
            }
        }
        if wal_path.exists() {
            // Torn-tail tolerant replay. A crash mid-append can leave
            // the final record truncated (or missing its newline); any
            // record past a torn write was never fsync-acknowledged, so
            // the correct recovery is to stop at the first unparsable
            // record and truncate the file back to the valid prefix —
            // not to fail the open, and never to touch the snapshot.
            let text = std::fs::read_to_string(&wal_path)?;
            let mut valid_bytes = 0usize;
            for line in text.split_inclusive('\n') {
                let trimmed = line.trim();
                let op = if trimmed.is_empty() {
                    None
                } else {
                    match Json::parse(trimmed).ok().as_ref().map(Op::from_json) {
                        Some(Ok(op)) => Some(op),
                        // Torn or corrupt record: drop it and the
                        // (unacknowledged) suffix behind it.
                        _ => {
                            crate::log_warn!(
                                "store: dropping torn wal tail at byte {valid_bytes} of {}",
                                wal_path.display()
                            );
                            break;
                        }
                    }
                };
                // A record is only valid if its newline made it to disk.
                if !line.ends_with('\n') {
                    crate::log_warn!(
                        "store: dropping unterminated wal record at byte {valid_bytes} of {}",
                        wal_path.display()
                    );
                    break;
                }
                valid_bytes += line.len();
                if let Some(op) = op {
                    op.apply(&mut leader);
                }
            }
            if valid_bytes < text.len() {
                let f = std::fs::OpenOptions::new().write(true).open(&wal_path)?;
                f.set_len(valid_bytes as u64).context("truncating torn wal tail")?;
                f.sync_data().context("wal truncate fsync")?;
            }
        }
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&wal_path)?;
        Ok(Arc::new(Store {
            inner: Mutex::new(Inner {
                followers: (0..followers)
                    .map(|_| Follower { map: leader.clone(), applied: 0 })
                    .collect(),
                leader,
                log: Vec::new(),
                commits: 0,
            }),
            commit: Mutex::new(()),
            wal_path: Some(wal_path),
            wal: Mutex::new(Some(file)),
        }))
    }

    /// Run a serializable transaction. The closure may read its own
    /// writes; returning Err aborts with no effects.
    pub fn txn<T, F>(&self, f: F) -> Result<T>
    where
        F: FnOnce(&mut Txn<'_>) -> Result<T>,
    {
        let _commit = self.commit.lock().unwrap();
        let mut inner = self.inner.lock().unwrap();
        // Split borrow: Txn borrows the leader map immutably.
        let base_ptr: *const Map = &inner.leader;
        let mut txn = Txn { base: unsafe { &*base_ptr }, writes: Vec::new() };
        let result = f(&mut txn)?;
        let writes = txn.writes;
        // Commit: WAL first (durability), then apply.
        if let Some(file) = self.wal.lock().unwrap().as_mut() {
            for op in &writes {
                writeln!(file, "{}", op.to_json()).context("wal append")?;
            }
            file.sync_data().context("wal fsync")?;
        }
        for op in &writes {
            op.apply(&mut inner.leader);
        }
        inner.log.extend(writes);
        inner.commits += 1;
        Ok(result)
    }

    /// Leader read (serializable with respect to transactions).
    pub fn get(&self, key: &str) -> Option<Json> {
        self.inner.lock().unwrap().leader.get(key).cloned()
    }

    /// Leader prefix scan.
    pub fn scan_prefix(&self, prefix: &str) -> Vec<(String, Json)> {
        self.inner
            .lock()
            .unwrap()
            .leader
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Convenience CAS: put `value` iff current value of `key` == `expect`.
    pub fn compare_and_set(&self, key: &str, expect: Option<&Json>, value: Json) -> Result<bool> {
        self.txn(|t| {
            let cur = t.get(key);
            if cur.as_ref() == expect {
                t.put(key, value.clone());
                Ok(true)
            } else {
                Ok(false)
            }
        })
    }

    /// Possibly-stale follower read.
    pub fn get_follower(&self, follower: usize, key: &str) -> Option<Json> {
        self.inner.lock().unwrap().followers[follower].map.get(key).cloned()
    }

    /// Advance replication: each follower applies up to `batch` log ops.
    pub fn tick_replication(&self, batch: usize) {
        let mut inner = self.inner.lock().unwrap();
        let log_ptr: *const Vec<Op> = &inner.log;
        let log = unsafe { &*log_ptr };
        for f in &mut inner.followers {
            let end = (f.applied + batch).min(log.len());
            for op in &log[f.applied..end] {
                op.apply(&mut f.map);
            }
            f.applied = end;
        }
    }

    /// Write a snapshot and truncate the WAL (compaction).
    pub fn checkpoint(&self) -> Result<()> {
        let _commit = self.commit.lock().unwrap();
        let inner = self.inner.lock().unwrap();
        if let Some(wal_path) = &self.wal_path {
            let snap_path = wal_path.with_extension("snap");
            let snapshot = Json::Obj(inner.leader.clone());
            std::fs::write(&snap_path, snapshot.to_string())?;
            // Truncate the WAL: snapshot now covers it.
            let file = std::fs::OpenOptions::new()
                .write(true)
                .truncate(true)
                .open(wal_path)?;
            *self.wal.lock().unwrap() = Some(
                std::fs::OpenOptions::new().append(true).open(wal_path)?,
            );
            drop(file);
        }
        Ok(())
    }

    pub fn commits(&self) -> u64 {
        self.inner.lock().unwrap().commits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ts-store-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d.join("store")
    }

    #[test]
    fn txn_read_write() {
        let s = Store::in_memory(0);
        s.txn(|t| {
            t.put("a", Json::num(1.0));
            t.put("b", Json::str("x"));
            assert_eq!(t.get("a"), Some(Json::num(1.0))); // read-your-writes
            Ok(())
        })
        .unwrap();
        assert_eq!(s.get("a"), Some(Json::num(1.0)));
        assert_eq!(s.get("missing"), None);
    }

    #[test]
    fn aborted_txn_has_no_effect() {
        let s = Store::in_memory(0);
        let r: Result<()> = s.txn(|t| {
            t.put("a", Json::num(1.0));
            anyhow::bail!("abort");
        });
        assert!(r.is_err());
        assert_eq!(s.get("a"), None);
    }

    #[test]
    fn delete_and_scan() {
        let s = Store::in_memory(0);
        s.txn(|t| {
            t.put("model/a", Json::num(1.0));
            t.put("model/b", Json::num(2.0));
            t.put("job/x", Json::num(3.0));
            Ok(())
        })
        .unwrap();
        assert_eq!(s.scan_prefix("model/").len(), 2);
        s.txn(|t| {
            t.delete("model/a");
            assert_eq!(t.scan_prefix("model/").len(), 1); // txn sees delete
            Ok(())
        })
        .unwrap();
        assert_eq!(s.scan_prefix("model/").len(), 1);
    }

    #[test]
    fn compare_and_set() {
        let s = Store::in_memory(0);
        assert!(s.compare_and_set("k", None, Json::num(1.0)).unwrap());
        assert!(!s.compare_and_set("k", None, Json::num(2.0)).unwrap());
        assert!(s
            .compare_and_set("k", Some(&Json::num(1.0)), Json::num(2.0))
            .unwrap());
        assert_eq!(s.get("k"), Some(Json::num(2.0)));
    }

    #[test]
    fn durability_across_reopen() {
        let path = tmp("durable");
        {
            let s = Store::open(&path, 0).unwrap();
            s.txn(|t| {
                t.put("model/a", Json::obj(vec![("v", Json::num(3.0))]));
                Ok(())
            })
            .unwrap();
            s.txn(|t| {
                t.delete("model/a");
                t.put("model/b", Json::num(7.0));
                Ok(())
            })
            .unwrap();
        }
        let s = Store::open(&path, 0).unwrap();
        assert_eq!(s.get("model/a"), None);
        assert_eq!(s.get("model/b"), Some(Json::num(7.0)));
    }

    #[test]
    fn checkpoint_compacts_and_recovers() {
        let path = tmp("ckpt");
        {
            let s = Store::open(&path, 0).unwrap();
            for i in 0..50 {
                s.txn(|t| {
                    t.put(&format!("k{i}"), Json::num(i as f64));
                    Ok(())
                })
                .unwrap();
            }
            s.checkpoint().unwrap();
            // Post-checkpoint writes land in the fresh WAL.
            s.txn(|t| {
                t.put("after", Json::Bool(true));
                Ok(())
            })
            .unwrap();
            let wal_len = std::fs::read_to_string(path.with_extension("wal"))
                .unwrap()
                .lines()
                .count();
            assert_eq!(wal_len, 1, "wal should be compacted");
        }
        let s = Store::open(&path, 0).unwrap();
        assert_eq!(s.get("k42"), Some(Json::num(42.0)));
        assert_eq!(s.get("after"), Some(Json::Bool(true)));
    }

    #[test]
    fn torn_wal_tail_dropped_on_replay() {
        use std::io::Write;
        let path = tmp("torn");
        {
            let s = Store::open(&path, 0).unwrap();
            s.txn(|t| {
                t.put("model/a", Json::num(1.0));
                t.put("model/b", Json::num(2.0));
                Ok(())
            })
            .unwrap();
        }
        // Simulate a crash mid-append: a half-written record with no
        // terminating newline at the end of the WAL.
        let wal = path.with_extension("wal");
        let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
        write!(f, "{{\"put\":\"model/junk\",\"v\":trunc").unwrap();
        drop(f);

        // Replay: committed keys survive, the torn record does not,
        // and the file is truncated back to the valid prefix.
        let s = Store::open(&path, 0).unwrap();
        assert_eq!(s.get("model/a"), Some(Json::num(1.0)));
        assert_eq!(s.get("model/b"), Some(Json::num(2.0)));
        assert_eq!(s.get("model/junk"), None);
        let text = std::fs::read_to_string(&wal).unwrap();
        assert!(!text.contains("junk"), "torn tail must be truncated away: {text}");

        // New commits append cleanly after the repair, and a further
        // reopen sees both old and new state.
        s.txn(|t| {
            t.put("model/c", Json::num(3.0));
            Ok(())
        })
        .unwrap();
        drop(s);
        let s = Store::open(&path, 0).unwrap();
        assert_eq!(s.get("model/a"), Some(Json::num(1.0)));
        assert_eq!(s.get("model/c"), Some(Json::num(3.0)));
    }

    #[test]
    fn torn_record_never_corrupts_snapshot() {
        use std::io::Write;
        let path = tmp("torn-snap");
        {
            let s = Store::open(&path, 0).unwrap();
            s.txn(|t| {
                t.put("k", Json::num(1.0));
                Ok(())
            })
            .unwrap();
            s.checkpoint().unwrap();
            s.txn(|t| {
                t.put("k", Json::num(2.0));
                Ok(())
            })
            .unwrap();
        }
        // A fully-written record followed by garbage: the good record
        // replays, the garbage (and anything after it) is dropped.
        let wal = path.with_extension("wal");
        let mut f = std::fs::OpenOptions::new().append(true).open(&wal).unwrap();
        writeln!(f, "not json at all").unwrap();
        writeln!(f, "{}", Op::Put("k".into(), Json::num(9.0)).to_json()).unwrap();
        drop(f);
        let s = Store::open(&path, 0).unwrap();
        // Snapshot value overridden by the valid WAL record; the
        // post-garbage record was never acknowledged and must not apply.
        assert_eq!(s.get("k"), Some(Json::num(2.0)));
    }

    #[test]
    fn followers_lag_until_tick() {
        let s = Store::in_memory(2);
        s.txn(|t| {
            t.put("a", Json::num(1.0));
            Ok(())
        })
        .unwrap();
        // Followers are stale (replication hasn't run).
        assert_eq!(s.get_follower(0, "a"), None);
        s.tick_replication(10);
        assert_eq!(s.get_follower(0, "a"), Some(Json::num(1.0)));
        assert_eq!(s.get_follower(1, "a"), Some(Json::num(1.0)));
    }

    #[test]
    fn concurrent_txns_serialize() {
        let s = Store::in_memory(0);
        s.txn(|t| {
            t.put("counter", Json::num(0.0));
            Ok(())
        })
        .unwrap();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        s.txn(|t| {
                            let cur = t.get("counter").unwrap().as_f64().unwrap();
                            t.put("counter", Json::num(cur + 1.0));
                            Ok(())
                        })
                        .unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // Serializable: no lost updates.
        assert_eq!(s.get("counter"), Some(Json::num(400.0)));
        assert_eq!(s.commits(), 401);
    }
}
