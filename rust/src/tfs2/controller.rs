//! The TFS² Controller (§3.1): "takes care of adding, removing and
//! updating users' models, as well as honoring canary and rollback
//! requests. It estimates the RAM required to serve a given model and
//! selects a serving job that has enough memory capacity."
//!
//! All state lives in the transactional [`Store`]; every operation is
//! one transaction, so a crashed controller resumes from durable state.
//! That includes **version labels** (`label/{model}/{label}` keys):
//! canary/stable mappings set through the controller survive a process
//! restart and are pushed back out to replicas by the Synchronizer.

use super::binpack::{best_fit, Bin};
use super::store::Store;
use crate::bail_kind;
use crate::base::error::ErrorKind;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// One model's desired state on a job (consumed by the Synchronizer).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelAssignment {
    pub name: String,
    pub base_path: String,
    pub versions: Vec<u64>,
    /// Durable (label → version) mappings to push to replicas.
    pub labels: Vec<(String, u64)>,
}

/// Desired state for one serving job (consumed by the Synchronizer).
#[derive(Debug, Clone, PartialEq)]
pub struct JobAssignment {
    pub job: String,
    /// The job's seed replica address.
    pub addr: String,
    /// Every live replica address (always contains at least `addr`);
    /// updated as the autoscaler grows/shrinks the job.
    pub replicas: Vec<String>,
    pub models: Vec<ModelAssignment>,
}

pub struct Controller {
    store: Arc<Store>,
}

impl Controller {
    pub fn new(store: Arc<Store>) -> Self {
        Controller { store }
    }

    // ------------------------------------------------------------- jobs

    /// Register a serving job and its memory capacity.
    pub fn register_job(&self, id: &str, addr: &str, capacity_bytes: u64) -> Result<()> {
        self.store.txn(|t| {
            t.put(
                &format!("job/{id}"),
                Json::obj(vec![
                    ("addr", Json::str(addr)),
                    ("capacity", Json::num(capacity_bytes as f64)),
                    ("used", Json::num(0.0)),
                ]),
            );
            Ok(())
        })
    }

    /// Record a job's live replica addresses (the fleet layer calls
    /// this after scaling). `desired_state` reports them; a job with no
    /// recorded replicas reports just its seed `addr`.
    pub fn set_job_replicas(&self, id: &str, replicas: &[String]) -> Result<()> {
        self.store.txn(|t| {
            let key = format!("job/{id}");
            let mut rec = t.get(&key).ok_or_else(|| anyhow!("job '{id}' not found"))?;
            if let Json::Obj(o) = &mut rec {
                o.insert(
                    "replicas".into(),
                    Json::Arr(replicas.iter().map(|a| Json::str(a.clone())).collect()),
                );
            }
            t.put(&key, rec);
            Ok(())
        })
    }

    fn bins(&self, t: &super::store::Txn<'_>) -> Vec<Bin> {
        t.scan_prefix("job/")
            .into_iter()
            .map(|(k, v)| Bin {
                id: k.trim_start_matches("job/").to_string(),
                capacity: v.get("capacity").and_then(|x| x.as_u64()).unwrap_or(0),
                used: v.get("used").and_then(|x| x.as_u64()).unwrap_or(0),
            })
            .collect()
    }

    // ----------------------------------------------------------- models

    /// "add model": place onto a job with enough free RAM (best-fit)
    /// and desire `initial_version`.
    pub fn add_model(
        &self,
        name: &str,
        base_path: &str,
        ram_bytes: u64,
        initial_version: u64,
    ) -> Result<String> {
        self.store.txn(|t| {
            if t.get(&format!("model/{name}")).is_some() {
                bail!("model '{name}' already exists");
            }
            let bins = self.bins(t);
            let slot = best_fit(&bins, ram_bytes)
                .ok_or_else(|| anyhow!("no serving job with {ram_bytes}B free"))?;
            let job = bins[slot].id.clone();
            // Charge the job.
            let job_key = format!("job/{job}");
            let mut job_rec = t.get(&job_key).unwrap();
            if let Json::Obj(o) = &mut job_rec {
                let used = o.get("used").and_then(|x| x.as_u64()).unwrap_or(0);
                o.insert("used".into(), Json::num((used + ram_bytes) as f64));
            }
            t.put(&job_key, job_rec);
            t.put(
                &format!("model/{name}"),
                Json::obj(vec![
                    ("base_path", Json::str(base_path)),
                    ("ram", Json::num(ram_bytes as f64)),
                    ("job", Json::str(job.clone())),
                    (
                        "desired",
                        Json::Arr(vec![Json::num(initial_version as f64)]),
                    ),
                    ("canary", Json::Bool(false)),
                ]),
            );
            Ok(job)
        })
    }

    /// "remove model": free its reservation and forget it.
    pub fn remove_model(&self, name: &str) -> Result<()> {
        self.store.txn(|t| {
            let key = format!("model/{name}");
            let rec = t.get(&key).ok_or_else(|| anyhow!("model '{name}' not found"))?;
            let ram = rec.get("ram").and_then(|x| x.as_u64()).unwrap_or(0);
            let job = rec.get("job").and_then(|x| x.as_str()).unwrap_or("").to_string();
            let job_key = format!("job/{job}");
            if let Some(mut job_rec) = t.get(&job_key) {
                if let Json::Obj(o) = &mut job_rec {
                    let used = o.get("used").and_then(|x| x.as_u64()).unwrap_or(0);
                    o.insert("used".into(), Json::num(used.saturating_sub(ram) as f64));
                }
                t.put(&job_key, job_rec);
            }
            t.delete(&key);
            // Labels go with the model — same transaction, no orphans.
            for (k, _) in t.scan_prefix(&format!("label/{name}/")) {
                t.delete(&k);
            }
            Ok(())
        })
    }

    // ----------------------------------------------------------- labels

    /// Durably attach (or move) `label` on `model` to `version`. The
    /// version must be in the model's desired set, mirroring the
    /// serving-side invariant that labels only point at servable
    /// versions. One transaction; survives controller restarts.
    pub fn set_version_label(&self, model: &str, label: &str, version: u64) -> Result<()> {
        if label.is_empty() {
            bail_kind!(ErrorKind::InvalidArgument, "model '{model}': empty version label");
        }
        self.store.txn(|t| {
            let rec = t
                .get(&format!("model/{model}"))
                .ok_or_else(|| ErrorKind::NotFound.err(format!("model '{model}' not found")))?;
            let desired: Vec<u64> = rec
                .get("desired")
                .and_then(|d| d.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_u64()).collect())
                .unwrap_or_default();
            if !desired.contains(&version) {
                bail_kind!(
                    ErrorKind::FailedPrecondition,
                    "cannot label {model}:{version} as '{label}': version is not desired \
                     (desired versions: {desired:?})"
                );
            }
            t.put(&format!("label/{model}/{label}"), Json::num(version as f64));
            Ok(())
        })
    }

    /// Durably drop a label. NotFound when it isn't set.
    pub fn delete_version_label(&self, model: &str, label: &str) -> Result<()> {
        self.store.txn(|t| {
            let key = format!("label/{model}/{label}");
            if t.get(&key).is_none() {
                bail_kind!(ErrorKind::NotFound, "model '{model}' has no label '{label}'");
            }
            t.delete(&key);
            Ok(())
        })
    }

    /// Resolve a label to its version — served from the store, so the
    /// answer is identical before and after a controller restart.
    pub fn resolve_label(&self, model: &str, label: &str) -> Result<u64> {
        match self.store.get(&format!("label/{model}/{label}")) {
            Some(v) => v
                .as_u64()
                .ok_or_else(|| anyhow!("corrupt label record for {model}/{label}")),
            None => {
                let known: Vec<String> =
                    self.version_labels(model).into_iter().map(|(l, _)| l).collect();
                Err(ErrorKind::NotFound.err(format!(
                    "model '{model}' has no version labeled '{label}' (known labels: {known:?})"
                )))
            }
        }
    }

    /// All (label, version) pairs of a model, label-sorted.
    pub fn version_labels(&self, model: &str) -> Vec<(String, u64)> {
        let prefix = format!("label/{model}/");
        self.store
            .scan_prefix(&prefix)
            .into_iter()
            .filter_map(|(k, v)| Some((k[prefix.len()..].to_string(), v.as_u64()?)))
            .collect()
    }

    /// Enable/disable canarying for a model (§2.1.1).
    pub fn set_canary(&self, name: &str, enabled: bool) -> Result<()> {
        self.update_model(name, |o| {
            o.insert("canary".into(), Json::Bool(enabled));
            Ok(())
        })
    }

    /// "add model version": with canary on, the previous primary keeps
    /// serving and the new version loads alongside; otherwise the new
    /// version replaces the old desired set.
    pub fn add_version(&self, name: &str, version: u64) -> Result<()> {
        self.update_model(name, |o| {
            let canary = o.get("canary").and_then(|x| x.as_bool()).unwrap_or(false);
            let mut desired = desired_of(o);
            if canary {
                // Keep the current primary (largest serving), add new.
                let primary = desired.iter().copied().max();
                desired = match primary {
                    Some(p) if p != version => vec![p, version],
                    _ => vec![version],
                };
            } else {
                desired = vec![version];
            }
            desired.sort_unstable();
            o.insert(
                "desired".into(),
                Json::Arr(desired.iter().map(|v| Json::num(*v as f64)).collect()),
            );
            Ok(())
        })
    }

    /// Promote the canary: newest desired version becomes sole primary.
    pub fn promote_canary(&self, name: &str) -> Result<()> {
        self.update_model(name, |o| {
            let desired = desired_of(o);
            let newest = desired
                .iter()
                .copied()
                .max()
                .ok_or_else(|| anyhow!("no desired versions"))?;
            o.insert("desired".into(), Json::Arr(vec![Json::num(newest as f64)]));
            Ok(())
        })
    }

    /// Roll back to a specific older version (§2.1.1).
    pub fn rollback(&self, name: &str, version: u64) -> Result<()> {
        self.update_model(name, |o| {
            o.insert("desired".into(), Json::Arr(vec![Json::num(version as f64)]));
            Ok(())
        })
    }

    fn update_model<F>(&self, name: &str, f: F) -> Result<()>
    where
        F: FnOnce(&mut std::collections::BTreeMap<String, Json>) -> Result<()>,
    {
        self.store.txn(|t| {
            let key = format!("model/{name}");
            let mut rec = t.get(&key).ok_or_else(|| anyhow!("model '{name}' not found"))?;
            match &mut rec {
                Json::Obj(o) => f(o)?,
                _ => bail!("corrupt model record"),
            }
            // Labels must never point outside the desired set: prune
            // any a version change orphaned (replace, promote,
            // rollback) in the same transaction.
            let desired = match &rec {
                Json::Obj(o) => desired_of(o),
                _ => Vec::new(),
            };
            for (k, v) in t.scan_prefix(&format!("label/{name}/")) {
                if v.as_u64().map_or(true, |ver| !desired.contains(&ver)) {
                    t.delete(&k);
                }
            }
            t.put(&key, rec);
            Ok(())
        })
    }

    // ------------------------------------------------------------ reads

    /// Desired versions of one model.
    pub fn desired_versions(&self, name: &str) -> Result<Vec<u64>> {
        let rec = self
            .store
            .get(&format!("model/{name}"))
            .ok_or_else(|| anyhow!("model '{name}' not found"))?;
        Ok(rec
            .get("desired")
            .and_then(|d| d.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_u64()).collect())
            .unwrap_or_default())
    }

    /// The job a model is placed on.
    pub fn placement(&self, name: &str) -> Option<String> {
        self.store
            .get(&format!("model/{name}"))
            .and_then(|r| r.get("job").and_then(|j| j.as_str()).map(str::to_string))
    }

    /// Full desired state per job (the Synchronizer's input),
    /// including replica addresses and durable labels.
    pub fn desired_state(&self) -> Vec<JobAssignment> {
        let jobs = self.store.scan_prefix("job/");
        let models = self.store.scan_prefix("model/");
        let labels = self.store.scan_prefix("label/");
        jobs.into_iter()
            .map(|(k, v)| {
                let job = k.trim_start_matches("job/").to_string();
                let addr = v
                    .get("addr")
                    .and_then(|a| a.as_str())
                    .unwrap_or("")
                    .to_string();
                let replicas = v
                    .get("replicas")
                    .and_then(|r| r.as_arr())
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(str::to_string))
                            .collect::<Vec<_>>()
                    })
                    .filter(|r| !r.is_empty())
                    .unwrap_or_else(|| vec![addr.clone()]);
                let assigned = models
                    .iter()
                    .filter(|(_, m)| {
                        m.get("job").and_then(|j| j.as_str()) == Some(job.as_str())
                    })
                    .map(|(mk, m)| {
                        let name = mk.trim_start_matches("model/").to_string();
                        let prefix = format!("label/{name}/");
                        let model_labels = labels
                            .iter()
                            .filter(|(lk, _)| lk.starts_with(&prefix))
                            .filter_map(|(lk, lv)| {
                                Some((lk[prefix.len()..].to_string(), lv.as_u64()?))
                            })
                            .collect();
                        ModelAssignment {
                            name,
                            base_path: m
                                .get("base_path")
                                .and_then(|b| b.as_str())
                                .unwrap_or("")
                                .to_string(),
                            versions: m
                                .get("desired")
                                .and_then(|d| d.as_arr())
                                .map(|a| a.iter().filter_map(|v| v.as_u64()).collect())
                                .unwrap_or_default(),
                            labels: model_labels,
                        }
                    })
                    .collect();
                JobAssignment { job, addr, replicas, models: assigned }
            })
            .collect()
    }
}

fn desired_of(o: &std::collections::BTreeMap<String, Json>) -> Vec<u64> {
    o.get("desired")
        .and_then(|d| d.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_u64()).collect())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> Controller {
        let c = Controller::new(Store::in_memory(0));
        c.register_job("job-0", "127.0.0.1:9000", 1000).unwrap();
        c.register_job("job-1", "127.0.0.1:9001", 500).unwrap();
        c
    }

    #[test]
    fn add_model_best_fit_placement() {
        let c = controller();
        // 400B fits both; best-fit picks the tighter job-1 (500 free).
        let job = c.add_model("m", "/models/m", 400, 1).unwrap();
        assert_eq!(job, "job-1");
        assert_eq!(c.placement("m"), Some("job-1".into()));
        assert_eq!(c.desired_versions("m").unwrap(), vec![1]);
    }

    #[test]
    fn capacity_is_charged_and_respected() {
        let c = controller();
        c.add_model("a", "/a", 400, 1).unwrap(); // job-1 (100 left)
        c.add_model("b", "/b", 400, 1).unwrap(); // job-0 (600 left)
        c.add_model("c", "/c", 600, 1).unwrap(); // job-0 (0 left)
        // Nothing has 200 free anymore except job-1's 100? No: fails.
        let err = c.add_model("d", "/d", 200, 1).unwrap_err();
        assert!(err.to_string().contains("no serving job"), "{err}");
        // Removing frees the reservation.
        c.remove_model("c").unwrap();
        assert_eq!(c.add_model("d", "/d", 200, 1).unwrap(), "job-0");
    }

    #[test]
    fn duplicate_add_rejected() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        assert!(c.add_model("m", "/m", 10, 1).is_err());
    }

    #[test]
    fn version_update_without_canary_replaces() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        c.add_version("m", 2).unwrap();
        assert_eq!(c.desired_versions("m").unwrap(), vec![2]);
    }

    #[test]
    fn canary_flow() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        c.set_canary("m", true).unwrap();
        // New version arrives: both serve (§2.1.1).
        c.add_version("m", 2).unwrap();
        assert_eq!(c.desired_versions("m").unwrap(), vec![1, 2]);
        // Confidence gained: promote.
        c.promote_canary("m").unwrap();
        assert_eq!(c.desired_versions("m").unwrap(), vec![2]);
    }

    #[test]
    fn rollback_flow() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        c.add_version("m", 2).unwrap();
        c.rollback("m", 1).unwrap();
        assert_eq!(c.desired_versions("m").unwrap(), vec![1]);
        // Fixed version arrives later; normal update resumes.
        c.add_version("m", 3).unwrap();
        assert_eq!(c.desired_versions("m").unwrap(), vec![3]);
    }

    #[test]
    fn desired_state_groups_by_job() {
        let c = controller();
        c.add_model("a", "/a", 400, 1).unwrap(); // job-1
        c.add_model("b", "/b", 600, 2).unwrap(); // job-0
        let state = c.desired_state();
        let job0 = state.iter().find(|j| j.job == "job-0").unwrap();
        let job1 = state.iter().find(|j| j.job == "job-1").unwrap();
        assert_eq!(job0.addr, "127.0.0.1:9000");
        // No explicit replica set: the seed addr is the only replica.
        assert_eq!(job0.replicas, vec!["127.0.0.1:9000".to_string()]);
        assert_eq!(
            job0.models,
            vec![ModelAssignment {
                name: "b".into(),
                base_path: "/b".into(),
                versions: vec![2],
                labels: vec![],
            }]
        );
        assert_eq!(job1.models[0].name, "a");
        assert_eq!(job1.models[0].versions, vec![1]);
    }

    #[test]
    fn job_replicas_recorded_and_reported() {
        let c = controller();
        c.set_job_replicas("job-0", &["a:1".into(), "a:2".into()]).unwrap();
        let state = c.desired_state();
        let job0 = state.iter().find(|j| j.job == "job-0").unwrap();
        assert_eq!(job0.replicas, vec!["a:1".to_string(), "a:2".to_string()]);
        assert!(c.set_job_replicas("nope", &[]).is_err());
    }

    #[test]
    fn label_lifecycle_and_validation() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        // Only desired versions may be labeled.
        let err = c.set_version_label("m", "stable", 9).unwrap_err();
        assert!(err.to_string().contains("not desired"), "{err}");
        assert!(c.set_version_label("m", "", 1).is_err());
        assert!(c.set_version_label("ghost", "stable", 1).is_err());

        c.set_version_label("m", "stable", 1).unwrap();
        assert_eq!(c.resolve_label("m", "stable").unwrap(), 1);
        // Resolution errors name what exists.
        let err = c.resolve_label("m", "canary").unwrap_err().to_string();
        assert!(err.contains("canary") && err.contains("stable"), "{err}");

        // Labels land in desired_state for the Synchronizer to push.
        let state = c.desired_state();
        let m = state
            .iter()
            .flat_map(|j| &j.models)
            .find(|m| m.name == "m")
            .unwrap();
        assert_eq!(m.labels, vec![("stable".to_string(), 1)]);

        c.delete_version_label("m", "stable").unwrap();
        assert!(c.resolve_label("m", "stable").is_err());
        assert!(c.delete_version_label("m", "stable").is_err()); // NotFound
    }

    #[test]
    fn version_changes_prune_orphaned_labels() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        c.set_canary("m", true).unwrap();
        c.add_version("m", 2).unwrap(); // desired {1, 2}
        c.set_version_label("m", "stable", 1).unwrap();
        c.set_version_label("m", "canary", 2).unwrap();
        // Promotion drops v1 from desired → its label goes too.
        c.promote_canary("m").unwrap();
        assert!(c.resolve_label("m", "stable").is_err());
        assert_eq!(c.resolve_label("m", "canary").unwrap(), 2);
        assert_eq!(c.version_labels("m"), vec![("canary".to_string(), 2)]);
    }

    #[test]
    fn remove_model_removes_labels() {
        let c = controller();
        c.add_model("m", "/m", 10, 1).unwrap();
        c.set_version_label("m", "stable", 1).unwrap();
        c.remove_model("m").unwrap();
        assert!(c.version_labels("m").is_empty());
    }

    #[test]
    fn labels_survive_controller_restart_from_disk() {
        // The label-persistence round-trip (satellite): set before a
        // simulated crash, resolve identically after — served from the
        // durable store, not controller memory.
        let dir = std::env::temp_dir().join(format!(
            "ts-ctrl-labels-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("store");
        {
            let c = Controller::new(Store::open(&path, 0).unwrap());
            c.register_job("j", "addr", 100).unwrap();
            c.add_model("m", "/m", 50, 1).unwrap();
            c.set_canary("m", true).unwrap();
            c.add_version("m", 2).unwrap();
            c.set_version_label("m", "stable", 1).unwrap();
            c.set_version_label("m", "canary", 2).unwrap();
        } // crash: store handle and controller dropped
        let c = Controller::new(Store::open(&path, 0).unwrap());
        assert_eq!(c.resolve_label("m", "stable").unwrap(), 1);
        assert_eq!(c.resolve_label("m", "canary").unwrap(), 2);
        assert_eq!(
            c.version_labels("m"),
            vec![("canary".to_string(), 2), ("stable".to_string(), 1)]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_model_errors() {
        let c = controller();
        assert!(c.add_version("nope", 1).is_err());
        assert!(c.rollback("nope", 1).is_err());
        assert!(c.remove_model("nope").is_err());
        assert!(c.desired_versions("nope").is_err());
    }

    #[test]
    fn state_survives_controller_restart() {
        let store = Store::in_memory(0);
        {
            let c = Controller::new(Arc::clone(&store));
            c.register_job("j", "addr", 100).unwrap();
            c.add_model("m", "/m", 50, 1).unwrap();
        }
        // "Crash" and rebuild from the same store.
        let c = Controller::new(store);
        assert_eq!(c.placement("m"), Some("j".into()));
        assert_eq!(c.desired_versions("m").unwrap(), vec![1]);
    }
}
