//! The TFS² Synchronizer (§3.1): "models are disseminated to a
//! Synchronizer job in each data center… The Synchronizer instructs
//! serving jobs which models/versions to keep loaded at a given time,
//! via a special RPC-based Source library component… and reports back
//! status. The Synchronizer informs a Router job which models are
//! successfully loaded in which serving jobs."
//!
//! Beyond version dissemination, the Synchronizer is the fleet's
//! sensory organ: [`Synchronizer::scrape_load`] pulls structured
//! metrics (`Request::Metrics`) from every replica — batching lane
//! depth, queue-delay p99, admission sheds — and aggregates them into
//! per-job [`JobLoad`] signals the Autoscaler scales from.

use super::controller::JobAssignment;
use super::store::Store;
use crate::rpc::client::ClientPool;
use crate::rpc::proto::{Request, Response};
use crate::util::json::Json;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Loaded-state record published for the Router:
/// `loaded/<model>` = array of replica addrs with that model ready.
pub struct Synchronizer {
    store: Arc<Store>,
    pool: Arc<ClientPool>,
    /// Last observed `admission.shed` per replica addr, so scrapes
    /// report deltas (new sheds since last pass) rather than the
    /// monotone counter.
    last_shed: Mutex<HashMap<String, f64>>,
}

/// Result of one reconciliation pass.
#[derive(Debug, Default, PartialEq)]
pub struct SyncReport {
    /// (replica, model) pairs instructed this pass.
    pub instructed: usize,
    /// (model, replica addr) pairs observed fully ready.
    pub ready: usize,
    /// Jobs with at least one unreachable replica.
    pub unreachable: Vec<String>,
}

/// Per-job load signals scraped from replica metrics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobLoad {
    /// Replicas that answered the metrics scrape.
    pub replicas: usize,
    /// Sum of `batch.*.lane_depth` across replicas: work sitting in
    /// batching lanes right now, the primary scaling signal.
    pub lane_depth: f64,
    /// Worst *cumulative* `batch.*.queue_delay_ns.p99` across replicas
    /// (since-boot distribution; kept for dashboards and `/metrics`).
    pub queue_delay_p99_ns: f64,
    /// Worst *windowed* `batch.*.queue_delay_ns.window.p99` across
    /// replicas — recent queue pressure, what SLO-breach scaling keys
    /// on (a long-healed spike must not pin the fleet scaled up).
    pub queue_delay_window_p99_ns: f64,
    /// Requests shed by admission control since the previous scrape.
    pub shed_delta: f64,
}

/// Windowed health of one (model, version) aggregated across every
/// replica serving it: what rollout gates evaluate each tick.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct VersionHealth {
    /// Requests observed in the current window (summed over replicas).
    pub requests: u64,
    /// Server-side failures (Internal / DeadlineExceeded) in window.
    pub errors: u64,
    /// Worst windowed latency p99 across replicas, nanoseconds.
    pub p99_ns: f64,
}

impl VersionHealth {
    /// Windowed error rate; 0 when no traffic was observed.
    pub fn error_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.errors as f64 / self.requests as f64
        }
    }
}

impl Synchronizer {
    pub fn new(store: Arc<Store>, pool: Arc<ClientPool>) -> Self {
        Synchronizer { store, pool, last_shed: Mutex::new(HashMap::new()) }
    }

    /// One pass: push desired versions and labels to every replica of
    /// every job (idempotent, like the aspired-versions API it
    /// drives), poll status, publish the routing table.
    pub fn sync_once(&self, desired: &[JobAssignment]) -> Result<SyncReport> {
        let mut report = SyncReport::default();
        let mut loaded: Vec<(String, String)> = Vec::new(); // (model, replica addr)

        for job in desired {
            let mut job_unreachable = false;
            for addr in job.replicas.iter().filter(|a| !a.is_empty()) {
                let mut replica_ok = true;
                for model in &job.models {
                    let req = Request::SetAspired {
                        model: model.name.clone(),
                        versions: model.versions.clone(),
                    };
                    match self.pool.call(addr, &req) {
                        Ok(Response::Ack) => report.instructed += 1,
                        Ok(other) => {
                            crate::log_warn!("{}/{addr}: unexpected {other:?}", job.job);
                            replica_ok = false;
                        }
                        Err(e) => {
                            crate::log_warn!("{}/{addr}: unreachable: {e}", job.job);
                            replica_ok = false;
                            break;
                        }
                    }
                }
                if !replica_ok {
                    job_unreachable = true;
                    continue;
                }
                // Poll status. A replica enters the *routing table* as
                // soon as ANY desired version is ready — a canary that
                // is still loading must not eject the stable version
                // from routing (hedged failover covers the rare
                // partially-loaded replica). `report.ready` keeps the
                // stricter all-versions-ready meaning reconcile loops
                // wait on.
                for model in &job.models {
                    let status = self
                        .pool
                        .call(addr, &Request::ModelStatus { model: model.name.clone() });
                    if let Ok(Response::ModelStatus { versions: states }) = status {
                        let ready_of = |v: &u64| {
                            states.iter().any(|(sv, st)| sv == v && st == "ready")
                        };
                        let any_ready = model.versions.iter().any(ready_of);
                        let all_ready = model.versions.iter().all(ready_of);
                        if any_ready {
                            loaded.push((model.name.clone(), addr.clone()));
                            // Labels attach only to serving versions,
                            // so they fan out once those are ready; a
                            // replica that just (re)started re-learns
                            // its canary/stable mappings here. Labels
                            // naming a still-loading version are
                            // rejected replica-side and retried next
                            // pass.
                            self.push_labels(&job.job, addr, model);
                        }
                        if all_ready && !model.versions.is_empty() {
                            report.ready += 1;
                        }
                    }
                }
            }
            if job_unreachable {
                report.unreachable.push(job.job.clone());
            }
        }

        // Publish the routing table transactionally.
        self.store.txn(|t| {
            // Clear stale entries for models we manage.
            for (key, _) in t.scan_prefix("loaded/") {
                t.delete(&key);
            }
            let mut by_model: std::collections::BTreeMap<String, Vec<Json>> =
                Default::default();
            for (model, addr) in &loaded {
                by_model
                    .entry(model.clone())
                    .or_default()
                    .push(Json::str(addr.clone()));
            }
            for (model, addrs) in by_model {
                t.put(&format!("loaded/{model}"), Json::Arr(addrs));
            }
            Ok(())
        })?;
        Ok(report)
    }

    /// Best-effort label dissemination: idempotent SetVersionLabel per
    /// desired mapping. Rejections are logged, never fatal — the next
    /// pass retries, and the Controller's store stays authoritative.
    fn push_labels(&self, job: &str, addr: &str, model: &super::controller::ModelAssignment) {
        for (label, version) in &model.labels {
            let req = Request::SetVersionLabel {
                model: model.name.clone(),
                label: label.clone(),
                version: *version,
            };
            match self.pool.call(addr, &req) {
                Ok(Response::Ack) => {}
                Ok(Response::Error { message, .. }) => {
                    crate::log_warn!(
                        "{job}/{addr}: label '{label}' -> {}:{version} rejected: {message}",
                        model.name
                    );
                }
                Ok(other) => {
                    crate::log_warn!("{job}/{addr}: unexpected {other:?}");
                }
                Err(e) => {
                    crate::log_warn!("{job}/{addr}: label push failed: {e}");
                }
            }
        }
    }

    /// Scrape structured metrics from every replica and aggregate
    /// per-job load signals. Unreachable replicas contribute nothing
    /// (and don't count toward `replicas`): a dead replica looks like
    /// a smaller job, which reads as *more* load per survivor — the
    /// conservative direction for scaling decisions.
    pub fn scrape_load(&self, desired: &[JobAssignment]) -> HashMap<String, JobLoad> {
        let mut out = HashMap::new();
        for job in desired {
            let mut load = JobLoad::default();
            for addr in job.replicas.iter().filter(|a| !a.is_empty()) {
                let samples = match self.pool.call(addr, &Request::Metrics) {
                    Ok(Response::Metrics { samples }) => samples,
                    _ => continue,
                };
                load.replicas += 1;
                for (name, value) in &samples {
                    if name.starts_with("batch.") && name.ends_with(".lane_depth") {
                        load.lane_depth += value;
                    } else if name.starts_with("batch.") && name.ends_with(".queue_delay_ns.p99")
                    {
                        load.queue_delay_p99_ns = load.queue_delay_p99_ns.max(*value);
                    } else if name.starts_with("batch.")
                        && name.ends_with(".queue_delay_ns.window.p99")
                    {
                        load.queue_delay_window_p99_ns =
                            load.queue_delay_window_p99_ns.max(*value);
                    } else if name == "admission.shed" {
                        let prev = self
                            .last_shed
                            .lock()
                            .unwrap()
                            .insert(addr.clone(), *value)
                            .unwrap_or(0.0);
                        load.shed_delta += (value - prev).max(0.0);
                    }
                }
            }
            out.insert(job.job.clone(), load);
        }
        out
    }

    /// Scrape the per-(model, version) windowed health series
    /// (`health.{model}.v{version}.*.window`) from every replica and
    /// aggregate: requests/errors summed, latency p99 maxed (the worst
    /// replica is the one a rollout gate must respect). Unreachable
    /// replicas contribute nothing.
    pub fn scrape_health(
        &self,
        desired: &[JobAssignment],
    ) -> HashMap<(String, u64), VersionHealth> {
        let mut out: HashMap<(String, u64), VersionHealth> = HashMap::new();
        for job in desired {
            for addr in job.replicas.iter().filter(|a| !a.is_empty()) {
                let samples = match self.pool.call(addr, &Request::Metrics) {
                    Ok(Response::Metrics { samples }) => samples,
                    _ => continue,
                };
                for (name, value) in &samples {
                    let Some(rest) = name.strip_prefix("health.") else { continue };
                    enum Field {
                        Requests,
                        Errors,
                        P99,
                    }
                    let (base, field) = if let Some(b) = rest.strip_suffix(".requests.window")
                    {
                        (b, Field::Requests)
                    } else if let Some(b) = rest.strip_suffix(".errors.window") {
                        (b, Field::Errors)
                    } else if let Some(b) = rest.strip_suffix(".latency_ns.window.p99") {
                        (b, Field::P99)
                    } else {
                        continue;
                    };
                    // `health.{model}.v{version}.…`; model names may
                    // themselves contain dots, so split on the *last*
                    // ".v" whose tail parses as a number.
                    let Some((model, ver)) = base.rsplit_once(".v") else { continue };
                    let Ok(version) = ver.parse::<u64>() else { continue };
                    let h = out.entry((model.to_string(), version)).or_default();
                    match field {
                        Field::Requests => h.requests += *value as u64,
                        Field::Errors => h.errors += *value as u64,
                        Field::P99 => h.p99_ns = h.p99_ns.max(*value),
                    }
                }
            }
        }
        out
    }

    /// The routing table the Router consumes.
    pub fn routing_table(&self) -> Vec<(String, Vec<String>)> {
        self.store
            .scan_prefix("loaded/")
            .into_iter()
            .map(|(k, v)| {
                (
                    k.trim_start_matches("loaded/").to_string(),
                    v.as_arr()
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use crate::tfs2::controller::ModelAssignment;

    /// Fake serving job: acks SetAspired + SetVersionLabel, reports
    /// everything ready, serves canned metrics.
    fn fake_job(
        ready: bool,
        shed: f64,
    ) -> (Arc<RpcServer>, Arc<Mutex<Vec<(String, Vec<u64>)>>>, Arc<Mutex<Vec<(String, u64)>>>)
    {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = Arc::clone(&seen);
        let labels = Arc::new(Mutex::new(Vec::new()));
        let labels2 = Arc::clone(&labels);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                Request::SetAspired { model, versions } => {
                    seen2.lock().unwrap().push((model, versions));
                    Response::Ack
                }
                Request::SetVersionLabel { label, version, .. } => {
                    labels2.lock().unwrap().push((label, version));
                    Response::Ack
                }
                Request::ModelStatus { .. } => Response::ModelStatus {
                    versions: if ready {
                        vec![(1, "ready".into()), (2, "ready".into())]
                    } else {
                        vec![(1, "loading".into())]
                    },
                },
                Request::Metrics => Response::Metrics {
                    samples: vec![
                        ("admission.shed".into(), shed),
                        ("batch.m.lane_depth".into(), 4.0),
                        ("batch.m.queue_delay_ns.p99".into(), 7.5e6),
                        ("batch.m.queue_delay_ns.window.p99".into(), 2.5e6),
                        ("health.m.v1.errors.window".into(), 1.0),
                        ("health.m.v1.latency_ns.window.p99".into(), 3.0e6),
                        ("health.m.v1.requests.window".into(), 20.0),
                        ("health.m.v2.errors.window".into(), 9.0),
                        ("health.m.v2.latency_ns.window.p99".into(), 8.0e6),
                        ("health.m.v2.requests.window".into(), 10.0),
                    ],
                },
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap();
        (server, seen, labels)
    }

    fn assignment(addrs: &[String]) -> Vec<JobAssignment> {
        vec![JobAssignment {
            job: "job-0".into(),
            addr: addrs.first().cloned().unwrap_or_default(),
            replicas: addrs.to_vec(),
            models: vec![ModelAssignment {
                name: "m".into(),
                base_path: "/m".into(),
                versions: vec![1],
                labels: vec![("stable".into(), 1)],
            }],
        }]
    }

    #[test]
    fn instructs_and_publishes_ready_models() {
        let (job, seen, labels) = fake_job(true, 0.0);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(Arc::clone(&store), Arc::new(ClientPool::new()));
        let report = sync
            .sync_once(&assignment(&[job.addr().to_string()]))
            .unwrap();
        assert_eq!(report.instructed, 1);
        assert_eq!(report.ready, 1);
        assert!(report.unreachable.is_empty());
        assert_eq!(seen.lock().unwrap().as_slice(), &[("m".to_string(), vec![1])]);
        // Labels ride along once the model is ready.
        assert_eq!(labels.lock().unwrap().as_slice(), &[("stable".to_string(), 1)]);
        let table = sync.routing_table();
        assert_eq!(table.len(), 1);
        assert_eq!(table[0].0, "m");
        assert_eq!(table[0].1, vec![job.addr().to_string()]);
    }

    #[test]
    fn every_replica_is_instructed_and_routed() {
        let (a, seen_a, _) = fake_job(true, 0.0);
        let (b, seen_b, _) = fake_job(true, 0.0);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(Arc::clone(&store), Arc::new(ClientPool::new()));
        let addrs = vec![a.addr().to_string(), b.addr().to_string()];
        let report = sync.sync_once(&assignment(&addrs)).unwrap();
        assert_eq!(report.instructed, 2);
        assert_eq!(report.ready, 2);
        assert_eq!(seen_a.lock().unwrap().len(), 1);
        assert_eq!(seen_b.lock().unwrap().len(), 1);
        // The routing table lists both replicas for the model.
        let table = sync.routing_table();
        assert_eq!(table[0].1, addrs);
    }

    #[test]
    fn not_ready_models_stay_out_of_routing_table() {
        let (job, _, labels) = fake_job(false, 0.0);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let report = sync
            .sync_once(&assignment(&[job.addr().to_string()]))
            .unwrap();
        assert_eq!(report.ready, 0);
        assert!(sync.routing_table().is_empty());
        // Labels never land on a replica that is not serving yet.
        assert!(labels.lock().unwrap().is_empty());
    }

    #[test]
    fn partially_ready_replica_stays_routable_but_not_ready() {
        // Stable v1 serving, canary v2 still loading: the replica must
        // stay in the routing table (stable traffic keeps flowing and
        // the labels keep fanning out), while `report.ready` — the
        // all-versions bar reconcile loops wait on — stays 0.
        let labels = Arc::new(Mutex::new(Vec::new()));
        let labels2 = Arc::clone(&labels);
        let job = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                Request::SetAspired { .. } => Response::Ack,
                Request::SetVersionLabel { label, version, .. } => {
                    labels2.lock().unwrap().push((label, version));
                    Response::Ack
                }
                Request::ModelStatus { .. } => Response::ModelStatus {
                    versions: vec![(1, "ready".into()), (2, "loading".into())],
                },
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap();
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let mut desired = assignment(&[job.addr().to_string()]);
        desired[0].models[0].versions = vec![1, 2];
        let report = sync.sync_once(&desired).unwrap();
        assert_eq!(report.ready, 0);
        let table = sync.routing_table();
        assert_eq!(table.len(), 1);
        assert_eq!(table[0].1, vec![job.addr().to_string()]);
        assert_eq!(labels.lock().unwrap().as_slice(), &[("stable".to_string(), 1)]);
    }

    #[test]
    fn unreachable_job_reported() {
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let report = sync
            .sync_once(&assignment(&["127.0.0.1:1".to_string()]))
            .unwrap();
        assert_eq!(report.unreachable, vec!["job-0".to_string()]);
        assert!(sync.routing_table().is_empty());
    }

    #[test]
    fn stale_routing_entries_cleared() {
        let (job, _, _) = fake_job(true, 0.0);
        let store = Store::in_memory(0);
        store
            .txn(|t| {
                t.put("loaded/old_model", Json::Arr(vec![Json::str("dead:1")]));
                Ok(())
            })
            .unwrap();
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        sync.sync_once(&assignment(&[job.addr().to_string()])).unwrap();
        let table = sync.routing_table();
        assert!(table.iter().all(|(m, _)| m != "old_model"));
    }

    #[test]
    fn scrape_aggregates_replicas_and_deltas_sheds() {
        let (a, _, _) = fake_job(true, 10.0);
        let (b, _, _) = fake_job(true, 3.0);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let desired = assignment(&[a.addr().to_string(), b.addr().to_string()]);

        let load = &sync.scrape_load(&desired)["job-0"];
        assert_eq!(load.replicas, 2);
        assert_eq!(load.lane_depth, 8.0); // 4.0 per replica, summed
        assert_eq!(load.queue_delay_p99_ns, 7.5e6); // max, not sum
        assert_eq!(load.queue_delay_window_p99_ns, 2.5e6); // windowed sibling
        assert_eq!(load.shed_delta, 13.0); // first scrape: full counters

        // Counters unchanged → second scrape reports zero new sheds.
        let load = &sync.scrape_load(&desired)["job-0"];
        assert_eq!(load.shed_delta, 0.0);
        assert_eq!(load.lane_depth, 8.0);
    }

    #[test]
    fn scrape_health_aggregates_per_version_across_replicas() {
        let (a, _, _) = fake_job(true, 0.0);
        let (b, _, _) = fake_job(true, 0.0);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let desired = assignment(&[
            a.addr().to_string(),
            b.addr().to_string(),
            "127.0.0.1:1".to_string(), // unreachable: contributes nothing
        ]);
        let health = sync.scrape_health(&desired);
        let v1 = &health[&("m".to_string(), 1)];
        // Counts summed over the two live replicas, p99 maxed.
        assert_eq!(v1.requests, 40);
        assert_eq!(v1.errors, 2);
        assert_eq!(v1.p99_ns, 3.0e6);
        assert!((v1.error_rate() - 0.05).abs() < 1e-9);
        let v2 = &health[&("m".to_string(), 2)];
        assert_eq!(v2.requests, 20);
        assert_eq!(v2.errors, 18);
        assert_eq!(v2.p99_ns, 8.0e6);
        assert!((v2.error_rate() - 0.9).abs() < 1e-9);
        // No traffic at all reads as healthy-by-absence (rate 0); the
        // rollout gate separately requires min_requests before acting.
        assert_eq!(VersionHealth::default().error_rate(), 0.0);
    }

    #[test]
    fn scrape_skips_unreachable_replicas() {
        let (a, _, _) = fake_job(true, 0.0);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let desired = assignment(&[a.addr().to_string(), "127.0.0.1:1".to_string()]);
        let load = &sync.scrape_load(&desired)["job-0"];
        assert_eq!(load.replicas, 1);
        assert_eq!(load.lane_depth, 4.0);
    }
}
