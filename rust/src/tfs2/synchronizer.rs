//! The TFS² Synchronizer (§3.1): "models are disseminated to a
//! Synchronizer job in each data center… The Synchronizer instructs
//! serving jobs which models/versions to keep loaded at a given time,
//! via a special RPC-based Source library component… and reports back
//! status. The Synchronizer informs a Router job which models are
//! successfully loaded in which serving jobs."

use super::controller::JobAssignment;
use super::store::Store;
use crate::rpc::client::ClientPool;
use crate::rpc::proto::{Request, Response};
use crate::util::json::Json;
use anyhow::Result;
use std::sync::Arc;

/// Loaded-state record published for the Router:
/// `loaded/<model>` = array of job addrs with that model ready.
pub struct Synchronizer {
    store: Arc<Store>,
    pool: Arc<ClientPool>,
}

/// Result of one reconciliation pass.
#[derive(Debug, Default, PartialEq)]
pub struct SyncReport {
    /// (job, model) pairs instructed this pass.
    pub instructed: usize,
    /// (model, job addr) pairs observed fully ready.
    pub ready: usize,
    /// Jobs that could not be reached.
    pub unreachable: Vec<String>,
}

impl Synchronizer {
    pub fn new(store: Arc<Store>, pool: Arc<ClientPool>) -> Self {
        Synchronizer { store, pool }
    }

    /// One pass: push desired versions to every job (idempotent, like
    /// the aspired-versions API it drives), poll status, publish the
    /// routing table.
    pub fn sync_once(&self, desired: &[JobAssignment]) -> Result<SyncReport> {
        let mut report = SyncReport::default();
        let mut loaded: Vec<(String, String)> = Vec::new(); // (model, addr)

        for job in desired {
            if job.addr.is_empty() {
                continue;
            }
            let mut job_ok = true;
            for (model, _base, versions) in &job.models {
                let req = Request::SetAspired {
                    model: model.clone(),
                    versions: versions.clone(),
                };
                match self.pool.call(&job.addr, &req) {
                    Ok(Response::Ack) => report.instructed += 1,
                    Ok(other) => {
                        crate::log_warn!("{}: unexpected {other:?}", job.job);
                        job_ok = false;
                    }
                    Err(e) => {
                        crate::log_warn!("{}: unreachable: {e}", job.job);
                        job_ok = false;
                        break;
                    }
                }
            }
            if !job_ok {
                report.unreachable.push(job.job.clone());
                continue;
            }
            // Poll status: a model counts as loaded when every desired
            // version reports ready.
            for (model, _base, versions) in &job.models {
                let status = self
                    .pool
                    .call(&job.addr, &Request::ModelStatus { model: model.clone() });
                if let Ok(Response::ModelStatus { versions: states }) = status {
                    let all_ready = versions.iter().all(|v| {
                        states.iter().any(|(sv, st)| sv == v && st == "ready")
                    });
                    if all_ready && !versions.is_empty() {
                        loaded.push((model.clone(), job.addr.clone()));
                        report.ready += 1;
                    }
                }
            }
        }

        // Publish the routing table transactionally.
        self.store.txn(|t| {
            // Clear stale entries for models we manage.
            for (key, _) in t.scan_prefix("loaded/") {
                t.delete(&key);
            }
            let mut by_model: std::collections::BTreeMap<String, Vec<Json>> =
                Default::default();
            for (model, addr) in &loaded {
                by_model
                    .entry(model.clone())
                    .or_default()
                    .push(Json::str(addr.clone()));
            }
            for (model, addrs) in by_model {
                t.put(&format!("loaded/{model}"), Json::Arr(addrs));
            }
            Ok(())
        })?;
        Ok(report)
    }

    /// The routing table the Router consumes.
    pub fn routing_table(&self) -> Vec<(String, Vec<String>)> {
        self.store
            .scan_prefix("loaded/")
            .into_iter()
            .map(|(k, v)| {
                (
                    k.trim_start_matches("loaded/").to_string(),
                    v.as_arr()
                        .map(|a| {
                            a.iter()
                                .filter_map(|x| x.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use std::sync::Mutex;

    /// Fake serving job: acks SetAspired, reports everything ready.
    fn fake_job(ready: bool) -> (Arc<RpcServer>, Arc<Mutex<Vec<(String, Vec<u64>)>>>) {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let seen2 = Arc::clone(&seen);
        let server = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| match req {
                Request::SetAspired { model, versions } => {
                    seen2.lock().unwrap().push((model, versions));
                    Response::Ack
                }
                Request::ModelStatus { .. } => Response::ModelStatus {
                    versions: if ready {
                        vec![(1, "ready".into()), (2, "ready".into())]
                    } else {
                        vec![(1, "loading".into())]
                    },
                },
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap();
        (server, seen)
    }

    fn assignment(addr: &str) -> Vec<JobAssignment> {
        vec![JobAssignment {
            job: "job-0".into(),
            addr: addr.into(),
            models: vec![("m".into(), "/m".into(), vec![1])],
        }]
    }

    #[test]
    fn instructs_and_publishes_ready_models() {
        let (job, seen) = fake_job(true);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(Arc::clone(&store), Arc::new(ClientPool::new()));
        let report = sync.sync_once(&assignment(&job.addr().to_string())).unwrap();
        assert_eq!(report.instructed, 1);
        assert_eq!(report.ready, 1);
        assert!(report.unreachable.is_empty());
        assert_eq!(seen.lock().unwrap().as_slice(), &[("m".to_string(), vec![1])]);
        let table = sync.routing_table();
        assert_eq!(table.len(), 1);
        assert_eq!(table[0].0, "m");
        assert_eq!(table[0].1, vec![job.addr().to_string()]);
    }

    #[test]
    fn not_ready_models_stay_out_of_routing_table() {
        let (job, _) = fake_job(false);
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let report = sync.sync_once(&assignment(&job.addr().to_string())).unwrap();
        assert_eq!(report.ready, 0);
        assert!(sync.routing_table().is_empty());
    }

    #[test]
    fn unreachable_job_reported() {
        let store = Store::in_memory(0);
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        let report = sync.sync_once(&assignment("127.0.0.1:1")).unwrap();
        assert_eq!(report.unreachable, vec!["job-0".to_string()]);
        assert!(sync.routing_table().is_empty());
    }

    #[test]
    fn stale_routing_entries_cleared() {
        let (job, _) = fake_job(true);
        let store = Store::in_memory(0);
        store
            .txn(|t| {
                t.put("loaded/old_model", Json::Arr(vec![Json::str("dead:1")]));
                Ok(())
            })
            .unwrap();
        let sync = Synchronizer::new(store, Arc::new(ClientPool::new()));
        sync.sync_once(&assignment(&job.addr().to_string())).unwrap();
        let table = sync.routing_table();
        assert!(table.iter().all(|(m, _)| m != "old_model"));
    }
}
