//! In-process TFS² cluster simulation: each "serving job" is a real
//! [`ModelServer`] listening on a loopback port, so the Controller /
//! Synchronizer / Router stack exercises real sockets end to end
//! (substituting for Borg jobs across datacenters — see DESIGN.md).

use crate::server::builder::ModelServer;
use crate::server::config::ServerConfig;
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One serving job (possibly with scaled-out replicas).
pub struct ClusterJob {
    pub id: String,
    pub capacity_bytes: u64,
    /// Primary + replicas; all serve the same assignments.
    pub servers: Vec<Arc<ModelServer>>,
}

impl ClusterJob {
    pub fn addr(&self) -> String {
        self.servers[0].addr().to_string()
    }

    pub fn replica_addrs(&self) -> Vec<String> {
        self.servers.iter().map(|s| s.addr().to_string()).collect()
    }
}

pub struct Cluster {
    pub artifacts_root: PathBuf,
    jobs: Mutex<HashMap<String, ClusterJob>>,
}

fn empty_job_config(artifacts_root: &PathBuf, fault_tag: String) -> ServerConfig {
    ServerConfig {
        port: 0,
        http_addr: None,
        artifacts_root: artifacts_root.clone(),
        // Jobs get models only via SetAspired (the RPC source);
        // fast polling so new versions appear promptly.
        poll_interval: Some(Duration::from_millis(50)),
        availability_preserving: true,
        load_threads: 2,
        ram_capacity_bytes: 0,
        batching: Default::default(),
        models: Vec::new(),
        // Every replica gets a distinct `rpc:{job}/{idx}` fault point,
        // so chaos tests can slow or fail ONE replica even though the
        // fault registry is process-global.
        fault_tag: Some(fault_tag),
        ..Default::default()
    }
}

impl Cluster {
    /// Start `n` empty serving jobs with the given RAM capacity each.
    pub fn start(n: usize, capacity_bytes: u64, artifacts_root: PathBuf) -> Result<Cluster> {
        let mut jobs = HashMap::new();
        for i in 0..n {
            let id = format!("job-{i}");
            let server =
                ModelServer::start(empty_job_config(&artifacts_root, format!("{id}/0")))?;
            jobs.insert(
                id.clone(),
                ClusterJob { id, capacity_bytes, servers: vec![server] },
            );
        }
        Ok(Cluster { artifacts_root, jobs: Mutex::new(jobs) })
    }

    /// Job ids + primary addresses (for Controller registration).
    pub fn jobs(&self) -> Vec<(String, String, u64)> {
        let mut out: Vec<(String, String, u64)> = self
            .jobs
            .lock()
            .unwrap()
            .values()
            .map(|j| (j.id.clone(), j.addr(), j.capacity_bytes))
            .collect();
        out.sort();
        out
    }

    /// All replica addresses of a job (for hedged routing).
    pub fn replica_addrs(&self, job: &str) -> Vec<String> {
        self.jobs
            .lock()
            .unwrap()
            .get(job)
            .map(|j| j.replica_addrs())
            .unwrap_or_default()
    }

    /// Apply an autoscaler decision: grow or shrink a job's replicas.
    /// New replicas start empty; the Synchronizer's next pass loads
    /// them (callers should re-sync after scaling).
    pub fn scale_to(&self, job: &str, replicas: usize) -> Result<()> {
        let mut jobs = self.jobs.lock().unwrap();
        let j = jobs
            .get_mut(job)
            .ok_or_else(|| anyhow::anyhow!("unknown job '{job}'"))?;
        while j.servers.len() < replicas.max(1) {
            let tag = format!("{job}/{}", j.servers.len());
            j.servers
                .push(ModelServer::start(empty_job_config(&self.artifacts_root, tag))?);
        }
        while j.servers.len() > replicas.max(1) {
            if let Some(s) = j.servers.pop() {
                s.stop();
            }
        }
        Ok(())
    }

    /// Push the same aspired state to every replica of a job (the
    /// Synchronizer handles the primary; this covers scale-outs).
    pub fn sync_replicas(
        &self,
        pool: &crate::rpc::client::ClientPool,
        job: &str,
        models: &[crate::tfs2::controller::ModelAssignment],
    ) -> Result<()> {
        for addr in self.replica_addrs(job) {
            for model in models {
                pool.call(
                    &addr,
                    &crate::rpc::proto::Request::SetAspired {
                        model: model.name.clone(),
                        versions: model.versions.clone(),
                    },
                )?;
            }
        }
        Ok(())
    }

    pub fn stop(&self) {
        for job in self.jobs.lock().unwrap().values() {
            for s in &job.servers {
                s.stop();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};

    #[test]
    fn cluster_starts_and_lists_jobs() {
        let cluster = Cluster::start(3, 1 << 30, default_artifacts_root()).unwrap();
        let jobs = cluster.jobs();
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].0, "job-0");
        assert!(jobs.iter().all(|(_, addr, _)| addr.contains(':')));
        cluster.stop();
    }

    #[test]
    fn scaling_changes_replica_count() {
        let cluster = Cluster::start(1, 1 << 30, default_artifacts_root()).unwrap();
        assert_eq!(cluster.replica_addrs("job-0").len(), 1);
        cluster.scale_to("job-0", 3).unwrap();
        assert_eq!(cluster.replica_addrs("job-0").len(), 3);
        cluster.scale_to("job-0", 1).unwrap();
        assert_eq!(cluster.replica_addrs("job-0").len(), 1);
        assert!(cluster.scale_to("nope", 2).is_err());
        cluster.stop();
    }

    #[test]
    fn jobs_accept_rpc_assignments() {
        if !artifacts_available() {
            return;
        }
        let cluster = Cluster::start(1, 1 << 30, default_artifacts_root()).unwrap();
        let pool = crate::rpc::client::ClientPool::new();
        cluster
            .sync_replicas(
                &pool,
                "job-0",
                &[crate::tfs2::controller::ModelAssignment {
                    name: "toy_table".into(),
                    base_path: String::new(),
                    versions: vec![1],
                    labels: Vec::new(),
                }],
            )
            .unwrap();
        // The job should load the table within a few poll cycles.
        let addr = cluster.replica_addrs("job-0")[0].clone();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            if let Ok(crate::rpc::proto::Response::Lookup { values: Some(v) }) = pool.call(
                &addr,
                &crate::rpc::proto::Request::Lookup {
                    table: "toy_table".into(),
                    key: "3".into(),
                },
            ) {
                assert_eq!(v, vec![3.0, 2.0]);
                break;
            }
            assert!(std::time::Instant::now() < deadline, "table never loaded");
            std::thread::sleep(Duration::from_millis(50));
        }
        cluster.stop();
    }
}
