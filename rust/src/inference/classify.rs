//! The Classification API (§2.2): typed, example-based inference for
//! models exported with the `classify` signature.

use super::example::{examples_to_tensor, Example};
use super::predict::HandleSource;
use anyhow::{bail, Result};

/// Classify request: a batch of canonical examples.
#[derive(Debug, Clone)]
pub struct ClassifyRequest {
    pub model: String,
    pub version: Option<u64>,
    pub examples: Vec<Example>,
}

/// Per-example result: argmax class + per-class log-probabilities.
#[derive(Debug, Clone, PartialEq)]
pub struct Classification {
    pub class: i32,
    pub log_probs: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    pub model_version: u64,
    pub results: Vec<Classification>,
}

/// Execute a classification request.
pub fn classify(handles: &dyn HandleSource, req: &ClassifyRequest) -> Result<ClassifyResponse> {
    if req.examples.is_empty() {
        bail!("classify: empty example list");
    }
    let handle = handles.hlo_handle(&req.model, req.version)?;
    let spec = &handle.spec;
    if spec.signature != "classify" {
        bail!(
            "model '{}' has signature '{}', not classify",
            req.model,
            spec.signature
        );
    }
    let input = examples_to_tensor(&req.examples, "x", spec.input_dim)?;
    let outputs = handle.run(&input)?;
    // The feature tensor came from the global pool; recycle it now
    // that the model has consumed it.
    input.recycle_into(&crate::util::pool::BufferPool::global());
    // Exported as (log_probs f32[B,C], class s32[B]).
    let log_probs = outputs[0].as_f32()?;
    let classes = outputs[1].as_i32()?;
    let results = (0..req.examples.len())
        .map(|i| Classification {
            class: classes.data()[i],
            log_probs: log_probs.row(i).to_vec(),
        })
        .collect();
    Ok(ClassifyResponse { model_version: handle.id().version, results })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::Loader;
    use crate::base::servable::ServableId;
    use crate::inference::example::Feature;
    use crate::lifecycle::basic_manager::BasicManager;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};
    use crate::runtime::hlo_servable::HloLoader;
    use crate::runtime::pjrt::XlaRuntime;
    use std::sync::Arc;
    use std::time::Duration;

    fn manager() -> Option<Arc<BasicManager>> {
        if !artifacts_available() {
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        let m = BasicManager::with_defaults();
        for (name, v) in [("mlp_classifier", 2u64), ("mlp_regressor", 2)] {
            let dir = default_artifacts_root().join(name).join(v.to_string());
            m.load_and_wait(
                ServableId::new(name, v),
                Arc::new(HloLoader::new(Arc::clone(&rt), dir)) as Arc<dyn Loader>,
                Duration::from_secs(60),
            )
            .unwrap();
        }
        Some(m)
    }

    fn example(seed: usize) -> Example {
        let x: Vec<f32> = (0..32).map(|j| ((seed * 31 + j) as f32).cos()).collect();
        Example::new().with("x", Feature::Floats(x))
    }

    #[test]
    fn classify_returns_valid_distributions() {
        let Some(m) = manager() else { return };
        let resp = classify(
            m.as_ref(),
            &ClassifyRequest {
                model: "mlp_classifier".into(),
                version: None,
                examples: (0..5).map(example).collect(),
            },
        )
        .unwrap();
        assert_eq!(resp.results.len(), 5);
        for r in &resp.results {
            assert_eq!(r.log_probs.len(), 4);
            assert!((0..4).contains(&r.class));
            let p: f32 = r.log_probs.iter().map(|x| x.exp()).sum();
            assert!((p - 1.0).abs() < 1e-4);
            // class is the argmax of log_probs
            let argmax = r
                .log_probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0 as i32;
            assert_eq!(r.class, argmax);
        }
    }

    #[test]
    fn classify_rejects_wrong_signature() {
        let Some(m) = manager() else { return };
        let err = classify(
            m.as_ref(),
            &ClassifyRequest {
                model: "mlp_regressor".into(),
                version: None,
                examples: vec![example(0)],
            },
        )
        .unwrap_err();
        assert!(err.to_string().contains("signature"), "{err}");
    }

    #[test]
    fn classify_rejects_empty_and_bad_features() {
        let Some(m) = manager() else { return };
        assert!(classify(
            m.as_ref(),
            &ClassifyRequest {
                model: "mlp_classifier".into(),
                version: None,
                examples: vec![],
            },
        )
        .is_err());
        // Wrong feature dimension.
        let bad = Example::new().with("x", Feature::Floats(vec![1.0; 3]));
        assert!(classify(
            m.as_ref(),
            &ClassifyRequest {
                model: "mlp_classifier".into(),
                version: None,
                examples: vec![bad],
            },
        )
        .is_err());
    }
}
