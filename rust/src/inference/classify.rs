//! The Classification API (§2.2): typed, example-based inference for
//! signatures exported with the `classify` method.

use super::example::Example;
use super::predict::{run_example_signature, HandleSource};
use super::ModelSpec;
use crate::base::error::ErrorKind;
use crate::runtime::pjrt::OutTensor;
use crate::serving::{DirectRunner, RunOptions, Runner};
use anyhow::{bail, Result};

/// Classify request: a batch of canonical examples against one
/// classify signature of a model.
#[derive(Debug, Clone)]
pub struct ClassifyRequest {
    pub spec: ModelSpec,
    /// Signature to invoke; `""` means the default serving signature.
    pub signature: String,
    pub examples: Vec<Example>,
}

impl ClassifyRequest {
    /// Legacy constructor: default signature, (model, version?) addressing.
    pub fn simple(
        model: impl Into<String>,
        version: Option<u64>,
        examples: Vec<Example>,
    ) -> Self {
        ClassifyRequest {
            spec: ModelSpec::named(model, version),
            signature: String::new(),
            examples,
        }
    }
}

/// Per-example result: argmax class + per-class log-probabilities.
#[derive(Debug, Clone, PartialEq)]
pub struct Classification {
    pub class: i32,
    pub log_probs: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    pub model_version: u64,
    pub results: Vec<Classification>,
}

/// The signature's sole output matching `pred` — ambiguity (two
/// matching outputs) is an error naming the candidates, never a silent
/// first-match binding.
pub(crate) fn sole_matching_output<'a>(
    sig_name: &str,
    named: &'a [(String, OutTensor)],
    what: &str,
    pred: impl Fn(&OutTensor) -> bool,
) -> Result<&'a OutTensor> {
    let mut hits = named.iter().filter(|(_, t)| pred(t));
    let first = hits.next().ok_or_else(|| {
        ErrorKind::InvalidArgument.err(format!("signature '{sig_name}' has no {what} output"))
    })?;
    if let Some(second) = hits.next() {
        return Err(ErrorKind::InvalidArgument.err(format!(
            "signature '{sig_name}' is ambiguous: both '{}' and '{}' are {what} outputs \
             — declare a narrower signature",
            first.0, second.0
        )));
    }
    Ok(&first.1)
}

/// Extract per-example classifications from a signature's named
/// outputs: the s32 output carries classes, the rank-2 f32 output the
/// per-class log-probabilities.
pub(crate) fn classification_results(
    sig_name: &str,
    named: &[(String, OutTensor)],
    n: usize,
) -> Result<Vec<Classification>> {
    let classes = sole_matching_output(sig_name, named, "s32 class", |t| {
        t.as_i32().is_ok()
    })?
    .as_i32()?;
    let log_probs = sole_matching_output(
        sig_name,
        named,
        "f32 [batch, classes] scores",
        |t| t.as_f32().map(|t| t.rank() == 2).unwrap_or(false),
    )?
    .as_f32()?;
    if classes.len() < n || log_probs.batch() < n {
        bail!(
            "signature '{sig_name}': outputs cover {} classes / {} score rows, want {n}",
            classes.len(),
            log_probs.batch()
        );
    }
    Ok((0..n)
        .map(|i| Classification {
            class: classes.data()[i],
            log_probs: log_probs.row(i).to_vec(),
        })
        .collect())
}

/// Execute a classification request, with servable execution going
/// through `runner` (the serving path passes its
/// [`crate::serving::SessionRegistry`] here so concurrent classifies
/// merge into shared device batches).
pub fn classify_with(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &ClassifyRequest,
) -> Result<ClassifyResponse> {
    classify_with_opts(handles, runner, req, &RunOptions::default())
}

/// [`classify_with`] plus per-request [`RunOptions`] (deadline
/// propagation).
pub fn classify_with_opts(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &ClassifyRequest,
    opts: &RunOptions,
) -> Result<ClassifyResponse> {
    if req.examples.is_empty() {
        return Err(ErrorKind::InvalidArgument.err("classify: empty example list"));
    }
    let (model_version, results) = run_example_signature(
        handles,
        runner,
        opts,
        &req.spec,
        &req.signature,
        "classify",
        &req.examples,
        |sig_name, named| classification_results(sig_name, named, req.examples.len()),
    )?;
    Ok(ClassifyResponse { model_version, results })
}

/// [`classify_with`] using unbatched direct execution.
pub fn classify(handles: &dyn HandleSource, req: &ClassifyRequest) -> Result<ClassifyResponse> {
    classify_with(handles, &DirectRunner, req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::Loader;
    use crate::base::servable::ServableId;
    use crate::inference::example::Feature;
    use crate::lifecycle::basic_manager::BasicManager;
    use crate::runtime::artifacts::{
        artifacts_available, default_artifacts_root, ArtifactSpec,
    };
    use crate::runtime::hlo_servable::{synthetic_loader, HloLoader};
    use crate::runtime::pjrt::XlaRuntime;
    use std::sync::Arc;
    use std::time::Duration;

    fn manager() -> Option<Arc<BasicManager>> {
        if !artifacts_available() {
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        let m = BasicManager::with_defaults();
        for (name, v) in [("mlp_classifier", 2u64), ("mlp_regressor", 2)] {
            let dir = default_artifacts_root().join(name).join(v.to_string());
            m.load_and_wait(
                ServableId::new(name, v),
                Arc::new(HloLoader::new(Arc::clone(&rt), dir)) as Arc<dyn Loader>,
                Duration::from_secs(60),
            )
            .unwrap();
        }
        Some(m)
    }

    fn example(seed: usize) -> Example {
        let x: Vec<f32> = (0..32).map(|j| ((seed * 31 + j) as f32).cos()).collect();
        Example::new().with("x", Feature::Floats(x))
    }

    #[test]
    fn classify_returns_valid_distributions() {
        let Some(m) = manager() else { return };
        let resp = classify(
            m.as_ref(),
            &ClassifyRequest::simple("mlp_classifier", None, (0..5).map(example).collect()),
        )
        .unwrap();
        assert_eq!(resp.results.len(), 5);
        for r in &resp.results {
            assert_eq!(r.log_probs.len(), 4);
            assert!((0..4).contains(&r.class));
            let p: f32 = r.log_probs.iter().map(|x| x.exp()).sum();
            assert!((p - 1.0).abs() < 1e-4);
            // class is the argmax of log_probs
            let argmax = r
                .log_probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0 as i32;
            assert_eq!(r.class, argmax);
        }
    }

    #[test]
    fn classify_rejects_wrong_signature() {
        let Some(m) = manager() else { return };
        let err = classify(
            m.as_ref(),
            &ClassifyRequest::simple("mlp_regressor", None, vec![example(0)]),
        )
        .unwrap_err();
        assert!(err.to_string().contains("signature"), "{err}");
    }

    #[test]
    fn classify_rejects_empty_and_bad_features() {
        let Some(m) = manager() else { return };
        assert!(classify(
            m.as_ref(),
            &ClassifyRequest::simple("mlp_classifier", None, vec![]),
        )
        .is_err());
        // Wrong feature dimension.
        let bad = Example::new().with("x", Feature::Floats(vec![1.0; 3]));
        assert!(classify(
            m.as_ref(),
            &ClassifyRequest::simple("mlp_classifier", None, vec![bad]),
        )
        .is_err());
    }

    #[test]
    fn classify_synthetic_end_to_end() {
        // Runs in every build: the synthetic engine honors the same
        // signature contract as compiled artifacts.
        let m = BasicManager::with_defaults();
        m.load_and_wait(
            ServableId::new("syn", 1),
            synthetic_loader(ArtifactSpec::synthetic_classifier("syn", 1, 8, 3)),
            Duration::from_secs(10),
        )
        .unwrap();
        let ex = |i: usize| {
            Example::new().with(
                "x",
                Feature::Floats((0..8).map(|j| ((i * 3 + j) as f32).sin()).collect()),
            )
        };
        let resp = classify(
            m.as_ref(),
            &ClassifyRequest::simple("syn", None, (0..4).map(ex).collect()),
        )
        .unwrap();
        assert_eq!(resp.model_version, 1);
        assert_eq!(resp.results.len(), 4);
        for r in &resp.results {
            assert_eq!(r.log_probs.len(), 3);
            assert!((0..3).contains(&r.class));
        }
        // Method mismatch reported clearly: classify against a
        // regress-only signature name.
        let err = classify(
            m.as_ref(),
            &ClassifyRequest {
                spec: ModelSpec::latest("syn"),
                signature: "nope".into(),
                examples: vec![ex(0)],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("nope"), "{err}");
    }
}
