//! The low-level tensor Predict API (§2.2: "a low-level tensor
//! interface that mirrors TensorFlow's `Session::Run()` API"), redesigned
//! around [`ModelSpec`] + named signatures:
//!
//! * requests carry a **map of named input tensors** validated against
//!   the servable's declared [`SignatureDef`] (per-tensor error
//!   messages name the offending tensor),
//! * responses return **named outputs** (the signature's output names
//!   zipped with the executable's output tuple),
//! * the model is addressed by name + version **or version label**
//!   (labels resolve through [`LabeledSource`]).
//!
//! The handler pattern is the paper's: fetch a servable handle from the
//! manager, dereference, run, discard the handle (which defers any
//! final free to the reclaim thread).

use super::example::{examples_to_tensor, Example};
use super::ModelSpec;
use crate::bail_kind;
use crate::base::error::ErrorKind;
use crate::base::servable::ServableHandle;
use crate::base::tensor::Tensor;
use crate::lifecycle::basic_manager::{BasicManager, VersionRequest};
use crate::lifecycle::labels::LabelResolver;
use crate::lifecycle::manager::AspiredVersionsManager;
use crate::runtime::artifacts::{ArtifactSpec, SignatureDef, TensorInfo};
use crate::runtime::hlo_servable::HloServable;
use crate::runtime::pjrt::OutTensor;
use crate::serving::{DirectRunner, RunOptions, Runner};
use anyhow::{bail, Result};

/// Anything that can resolve HLO servable handles from a [`ModelSpec`]
/// (both manager layers, plus [`LabeledSource`] for label-aware
/// paths).
pub trait HandleSource: Send + Sync {
    fn hlo_handle(&self, spec: &ModelSpec) -> Result<ServableHandle<HloServable>>;
}

/// Reject labels on lookup paths that have no resolver, and map the
/// spec onto a concrete [`VersionRequest`].
fn version_request(spec: &ModelSpec) -> Result<VersionRequest> {
    if let Some(label) = &spec.label {
        bail_kind!(
            ErrorKind::InvalidArgument,
            "model '{}': version label '{label}' cannot be resolved on this lookup path \
             (no label resolver)",
            spec.name
        );
    }
    Ok(spec
        .version
        .map_or(VersionRequest::Latest, VersionRequest::Specific))
}

impl HandleSource for BasicManager {
    fn hlo_handle(&self, spec: &ModelSpec) -> Result<ServableHandle<HloServable>> {
        self.handle(&spec.name, version_request(spec)?)
    }
}

impl HandleSource for AspiredVersionsManager {
    fn hlo_handle(&self, spec: &ModelSpec) -> Result<ServableHandle<HloServable>> {
        self.handle(&spec.name, version_request(spec)?)
    }
}

/// Resolve a spec to a concrete version choice through a label
/// resolver: pinning **both** a version and a label is rejected, a
/// label resolves to its pinned version, and `None` means "latest".
/// Shared by the lookup path ([`LabeledSource`]) and
/// `GetModelMetadata`, so both enforce the same rule.
pub fn resolve_spec_version(
    labels: &LabelResolver,
    spec: &ModelSpec,
) -> Result<Option<u64>> {
    match (spec.version, &spec.label) {
        (Some(v), Some(label)) => bail_kind!(
            ErrorKind::InvalidArgument,
            "model '{}': request pins both version {v} and label '{label}' — use one",
            spec.name
        ),
        (Some(v), None) => Ok(Some(v)),
        (None, Some(label)) => Ok(Some(labels.resolve(&spec.name, label)?)),
        (None, None) => Ok(None),
    }
}

/// A [`HandleSource`] that resolves version labels through a
/// [`LabelResolver`] before delegating — the lookup path the server's
/// RPC handlers use. Consulted on every labeled lookup; unlabeled
/// lookups pass straight through.
pub struct LabeledSource<'a> {
    pub inner: &'a dyn HandleSource,
    pub labels: &'a LabelResolver,
}

impl HandleSource for LabeledSource<'_> {
    fn hlo_handle(&self, spec: &ModelSpec) -> Result<ServableHandle<HloServable>> {
        if spec.label.is_none() {
            return self.inner.hlo_handle(spec);
        }
        let version = resolve_spec_version(self.labels, spec)?;
        self.inner.hlo_handle(&ModelSpec {
            name: spec.name.clone(),
            version,
            label: None,
        })
    }
}

/// Predict request: named input tensors for a (model spec, signature).
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub spec: ModelSpec,
    /// Signature to invoke; `""` means the default serving signature.
    pub signature: String,
    /// Named inputs. A single entry with an empty name binds
    /// positionally to the signature's sole declared input (the legacy
    /// single-tensor form).
    pub inputs: Vec<(String, Tensor)>,
}

impl PredictRequest {
    /// Thin legacy constructor: one unnamed tensor against the default
    /// serving signature (what the sim/workload layer and benches
    /// issue).
    pub fn single(name: impl Into<String>, version: Option<u64>, input: Tensor) -> Self {
        PredictRequest {
            spec: ModelSpec::named(name, version),
            signature: String::new(),
            inputs: vec![(String::new(), input)],
        }
    }
}

/// Predict response: named output tensors + the version that served it.
#[derive(Debug, Clone)]
pub struct PredictResponse {
    pub model_version: u64,
    pub outputs: Vec<(String, OutTensor)>,
}

impl PredictResponse {
    /// Fetch one output by name.
    pub fn output(&self, name: &str) -> Result<&OutTensor> {
        self.outputs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no output named '{name}' (outputs: {:?})",
                    self.outputs.iter().map(|(n, _)| n).collect::<Vec<_>>()
                )
            })
    }
}

/// The signature's single declared input (the HLO runtime compiles
/// single-input executables; multi-input signatures are rejected with
/// a clear error rather than silently misbound).
pub(crate) fn sole_input<'a>(
    model: &str,
    sig_name: &str,
    sig: &'a SignatureDef,
) -> Result<&'a TensorInfo> {
    match sig.inputs.as_slice() {
        [one] => Ok(one),
        many => bail_kind!(
            ErrorKind::InvalidArgument,
            "model '{model}' signature '{sig_name}': {} declared inputs; the HLO runtime \
             serves single-input signatures only",
            many.len()
        ),
    }
}

/// Validate the request's named inputs against the signature and
/// return the tensor bound to its sole declared input. Every error
/// names the offending tensor.
pub(crate) fn bind_input<'a>(
    model: &str,
    sig_name: &str,
    sig: &SignatureDef,
    inputs: &'a [(String, Tensor)],
) -> Result<&'a Tensor> {
    let declared = sole_input(model, sig_name, sig)?;
    let bound = match inputs {
        [] => bail_kind!(
            ErrorKind::InvalidArgument,
            "model '{model}' signature '{sig_name}': missing input tensor '{}'",
            declared.name
        ),
        // Positional single-tensor form.
        [(name, t)] if name.is_empty() => t,
        named => {
            let mut found = None;
            for (name, t) in named {
                if name == &declared.name {
                    if found.is_some() {
                        bail_kind!(
                            ErrorKind::InvalidArgument,
                            "model '{model}' signature '{sig_name}': input tensor \
                             '{name}' supplied more than once"
                        );
                    }
                    found = Some(t);
                } else {
                    bail_kind!(
                        ErrorKind::InvalidArgument,
                        "model '{model}' signature '{sig_name}': unexpected input tensor \
                         '{name}' (declared inputs: [\"{}\"])",
                        declared.name
                    );
                }
            }
            found.ok_or_else(|| {
                ErrorKind::InvalidArgument.err(format!(
                    "model '{model}' signature '{sig_name}': missing input tensor '{}'",
                    declared.name
                ))
            })?
        }
    };
    if !declared.matches_shape(bound.shape()) {
        bail_kind!(
            ErrorKind::InvalidArgument,
            "model '{model}' signature '{sig_name}': input tensor '{}' has shape {:?}, \
             want {:?}",
            declared.name,
            bound.shape(),
            declared.shape
        );
    }
    Ok(bound)
}

/// Zip a signature's output names with the executable's output tuple
/// (cheap: each output is an O(1) view clone).
pub(crate) fn name_outputs(
    spec: &ArtifactSpec,
    sig_name: &str,
    sig: &SignatureDef,
    outputs: &[OutTensor],
) -> Result<Vec<(String, OutTensor)>> {
    sig.outputs
        .iter()
        .map(|info| {
            let idx = spec.output_index(&info.name).ok_or_else(|| {
                anyhow::anyhow!(
                    "model '{}' signature '{sig_name}': output '{}' not in executable \
                     outputs {:?}",
                    spec.model_name,
                    info.name,
                    spec.output_names()
                )
            })?;
            match outputs.get(idx) {
                Some(t) => Ok((info.name.clone(), t.clone())),
                None => bail!(
                    "model '{}': executable returned {} outputs, signature '{sig_name}' \
                     expects index {idx} ('{}')",
                    spec.model_name,
                    outputs.len(),
                    info.name
                ),
            }
        })
        .collect()
}

/// Hand output-tensor storage back to the global pools (the pool
/// declines anything shared or not class-sized, so this is always
/// safe).
pub(crate) fn recycle_out_tensors(outputs: Vec<OutTensor>) {
    for t in outputs {
        match t {
            OutTensor::F32(t) => t.recycle_into(&crate::util::pool::BufferPool::global()),
            OutTensor::I32(t) => {
                t.recycle_into(&crate::util::pool::BufferPool::global_i32())
            }
        }
    }
}

/// The shared classify/regress pipeline: validate the signature's
/// method, build the feature tensor from the examples, run the
/// servable **through the runner** (the serving path's cross-request
/// batching seam), extract the typed result from the named outputs,
/// and recycle both the input and the output storage (error paths
/// included). Returns `(serving version, extracted result)`.
pub(crate) fn run_example_signature<T>(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    opts: &RunOptions,
    spec: &ModelSpec,
    signature: &str,
    method: &str,
    examples: &[Example],
    extract: impl FnOnce(&str, &[(String, OutTensor)]) -> Result<T>,
) -> Result<(u64, T)> {
    let handle = handles.hlo_handle(spec)?;
    let (sig_name, sig) = handle.spec.signature_def(signature)?;
    if sig.method != method {
        bail_kind!(
            ErrorKind::InvalidArgument,
            "model '{}' signature '{sig_name}' has method '{}', not {method}",
            spec.name,
            sig.method
        );
    }
    let input_info = sole_input(&spec.name, sig_name, sig)?;
    let input = examples_to_tensor(examples, &input_info.name, handle.spec.input_dim)?;
    let run = runner.run_opts(&handle, &input, opts);
    // The feature tensor came from the global pool; recycle it whether
    // or not the run succeeded (error paths must not leak pool misses).
    input.recycle_into(&crate::util::pool::BufferPool::global());
    let outputs = run?;
    let named = name_outputs(&handle.spec, sig_name, sig, &outputs)?;
    let result = extract(sig_name, &named);
    // The view clones in `named` drop first so the sole-owner gate
    // accepts the output storage back.
    drop(named);
    recycle_out_tensors(outputs);
    Ok((handle.id().version, result?))
}

/// Execute a predict request against a handle source, with execution
/// going through `runner` — hand in a
/// [`crate::serving::SessionRegistry`] and concurrent predicts merge
/// into shared device batches.
pub fn predict_with(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &PredictRequest,
) -> Result<PredictResponse> {
    predict_with_opts(handles, runner, req, &RunOptions::default())
}

/// [`predict_with`] plus per-request [`RunOptions`] (the deadline
/// propagation seam: an expired deadline is refused before the device
/// call, wherever the request is when it lapses).
pub fn predict_with_opts(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &PredictRequest,
    opts: &RunOptions,
) -> Result<PredictResponse> {
    let handle = handles.hlo_handle(&req.spec)?;
    let (sig_name, sig) = handle.spec.signature_def(&req.signature)?;
    let input = bind_input(&req.spec.name, sig_name, sig, &req.inputs)?;
    let raw = runner.run_opts(&handle, input, opts)?;
    let named = name_outputs(&handle.spec, sig_name, sig, &raw)?;
    // Recycle outputs the signature did not select (sole owners);
    // selected ones are still referenced by `named` and the pool
    // declines them.
    recycle_out_tensors(raw);
    Ok(PredictResponse { model_version: handle.id().version, outputs: named })
    // handle drops here → refs retired via the reclaim thread
}

/// [`predict_with`] using unbatched direct execution (library callers
/// without a serving stack).
pub fn predict(handles: &dyn HandleSource, req: &PredictRequest) -> Result<PredictResponse> {
    predict_with(handles, &DirectRunner, req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::Loader;
    use crate::base::servable::ServableId;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};
    use crate::runtime::hlo_servable::{synthetic_loader, HloLoader};
    use crate::runtime::pjrt::XlaRuntime;
    use std::sync::Arc;
    use std::time::Duration;

    fn manager_with_classifier() -> Option<Arc<BasicManager>> {
        if !artifacts_available() {
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        let m = BasicManager::with_defaults();
        for v in [1u64, 2] {
            let dir = default_artifacts_root().join("mlp_classifier").join(v.to_string());
            m.load_and_wait(
                ServableId::new("mlp_classifier", v),
                Arc::new(HloLoader::new(Arc::clone(&rt), dir)) as Arc<dyn Loader>,
                Duration::from_secs(60),
            )
            .unwrap();
        }
        Some(m)
    }

    /// Synthetic two-version manager: runs in every build.
    fn manager_with_synthetic() -> Arc<BasicManager> {
        let m = BasicManager::with_defaults();
        for v in [1u64, 2] {
            m.load_and_wait(
                ServableId::new("syn", v),
                synthetic_loader(ArtifactSpec::synthetic_classifier("syn", v, 8, 3)),
                Duration::from_secs(10),
            )
            .unwrap();
        }
        m
    }

    #[test]
    fn predict_latest_and_specific() {
        let Some(m) = manager_with_classifier() else { return };
        let req = PredictRequest::single("mlp_classifier", None, Tensor::zeros(vec![2, 32]));
        let resp = predict(m.as_ref(), &req).unwrap();
        assert_eq!(resp.model_version, 2); // latest
        assert_eq!(resp.outputs.len(), 2);
        assert_eq!(resp.output("log_probs").unwrap().as_f32().unwrap().shape(), &[2, 4]);
        assert_eq!(resp.output("class").unwrap().as_i32().unwrap().shape(), &[2]);

        let resp1 = predict(
            m.as_ref(),
            &PredictRequest::single("mlp_classifier", Some(1), Tensor::zeros(vec![2, 32])),
        )
        .unwrap();
        assert_eq!(resp1.model_version, 1);
    }

    #[test]
    fn predict_missing_model_errors() {
        let m = manager_with_synthetic();
        let req = PredictRequest::single("nope", None, Tensor::zeros(vec![1, 8]));
        assert!(predict(m.as_ref(), &req).is_err());
    }

    #[test]
    fn predict_synthetic_named_inputs_and_outputs() {
        let m = manager_with_synthetic();
        // Explicitly named input "x" against the default signature.
        let req = PredictRequest {
            spec: ModelSpec::latest("syn"),
            signature: String::new(),
            inputs: vec![("x".into(), Tensor::zeros(vec![3, 8]))],
        };
        let resp = predict(m.as_ref(), &req).unwrap();
        assert_eq!(resp.model_version, 2);
        assert_eq!(
            resp.outputs.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            vec!["log_probs", "class"]
        );
        assert!(resp.output("missing").is_err());
    }

    #[test]
    fn predict_validation_names_the_offending_tensor() {
        let m = manager_with_synthetic();
        // Unknown input name.
        let err = predict(
            m.as_ref(),
            &PredictRequest {
                spec: ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![("bogus".into(), Tensor::zeros(vec![1, 8]))],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("bogus") && err.contains('x'), "{err}");
        // Wrong shape, named input.
        let err = predict(
            m.as_ref(),
            &PredictRequest {
                spec: ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 5]))],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("'x'") && err.contains("[1, 5]"), "{err}");
        // Unknown signature.
        let err = predict(
            m.as_ref(),
            &PredictRequest {
                spec: ModelSpec::latest("syn"),
                signature: "nope".into(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("nope") && err.contains("serving_default"), "{err}");
        // No inputs at all.
        let err = predict(
            m.as_ref(),
            &PredictRequest {
                spec: ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("missing input tensor 'x'"), "{err}");
        // Duplicate named input rejected, not silently last-wins.
        let err = predict(
            m.as_ref(),
            &PredictRequest {
                spec: ModelSpec::latest("syn"),
                signature: String::new(),
                inputs: vec![
                    ("x".into(), Tensor::zeros(vec![1, 8])),
                    ("x".into(), Tensor::zeros(vec![1, 8])),
                ],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("more than once"), "{err}");
    }

    #[test]
    fn labels_resolve_through_labeled_source() {
        let m = manager_with_synthetic();
        let labels = LabelResolver::new();
        labels.set("syn", "stable", 1, &[1, 2]).unwrap();
        labels.set("syn", "canary", 2, &[1, 2]).unwrap();
        let source = LabeledSource { inner: m.as_ref(), labels: &labels };
        for (label, want) in [("stable", 1u64), ("canary", 2)] {
            let resp = predict(
                &source,
                &PredictRequest {
                    spec: ModelSpec::with_label("syn", label),
                    signature: String::new(),
                    inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
                },
            )
            .unwrap();
            assert_eq!(resp.model_version, want, "label {label}");
        }
        // Unknown label surfaces the resolver's error.
        let err = predict(
            &source,
            &PredictRequest {
                spec: ModelSpec::with_label("syn", "ghost"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("ghost"), "{err}");
        // Version + label together rejected.
        let mut spec = ModelSpec::with_label("syn", "stable");
        spec.version = Some(2);
        let err = predict(
            &source,
            &PredictRequest {
                spec,
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("both"), "{err}");
        // Labels on a resolver-less path are rejected, not ignored.
        let err = predict(
            m.as_ref(),
            &PredictRequest {
                spec: ModelSpec::with_label("syn", "stable"),
                signature: String::new(),
                inputs: vec![("x".into(), Tensor::zeros(vec![1, 8]))],
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("no label resolver"), "{err}");
    }
}
