//! The low-level tensor Predict API (§2.2: "a low-level tensor
//! interface that mirrors TensorFlow's `Session::Run()` API").
//!
//! The handler pattern is the paper's: fetch a servable handle from the
//! manager, dereference, run, discard the handle (which defers any
//! final free to the reclaim thread).

use crate::base::servable::ServableHandle;
use crate::base::tensor::Tensor;
use crate::lifecycle::basic_manager::{BasicManager, VersionRequest};
use crate::lifecycle::manager::AspiredVersionsManager;
use crate::runtime::hlo_servable::HloServable;
use crate::runtime::pjrt::OutTensor;
use anyhow::Result;

/// Anything that can resolve HLO servable handles (both manager layers).
pub trait HandleSource: Send + Sync {
    fn hlo_handle(
        &self,
        name: &str,
        version: Option<u64>,
    ) -> Result<ServableHandle<HloServable>>;
}

impl HandleSource for BasicManager {
    fn hlo_handle(
        &self,
        name: &str,
        version: Option<u64>,
    ) -> Result<ServableHandle<HloServable>> {
        self.handle(
            name,
            version.map_or(VersionRequest::Latest, VersionRequest::Specific),
        )
    }
}

impl HandleSource for AspiredVersionsManager {
    fn hlo_handle(
        &self,
        name: &str,
        version: Option<u64>,
    ) -> Result<ServableHandle<HloServable>> {
        self.handle(
            name,
            version.map_or(VersionRequest::Latest, VersionRequest::Specific),
        )
    }
}

/// Predict request: raw input tensor for a (model, version?).
#[derive(Debug, Clone)]
pub struct PredictRequest {
    pub model: String,
    /// `None` = latest ready version.
    pub version: Option<u64>,
    pub input: Tensor,
}

/// Predict response: output tuple + the version that served it.
#[derive(Debug, Clone)]
pub struct PredictResponse {
    pub model_version: u64,
    pub outputs: Vec<OutTensor>,
}

/// Execute a predict request against a manager.
pub fn predict(handles: &dyn HandleSource, req: &PredictRequest) -> Result<PredictResponse> {
    let handle = handles.hlo_handle(&req.model, req.version)?;
    let outputs = handle.run(&req.input)?;
    Ok(PredictResponse { model_version: handle.id().version, outputs })
    // handle drops here → refs retired via the reclaim thread
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::Loader;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};
    use crate::runtime::hlo_servable::HloLoader;
    use crate::runtime::pjrt::XlaRuntime;
    use crate::base::servable::ServableId;
    use std::sync::Arc;
    use std::time::Duration;

    fn manager_with_classifier() -> Option<Arc<BasicManager>> {
        if !artifacts_available() {
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        let m = BasicManager::with_defaults();
        for v in [1u64, 2] {
            let dir = default_artifacts_root().join("mlp_classifier").join(v.to_string());
            m.load_and_wait(
                ServableId::new("mlp_classifier", v),
                Arc::new(HloLoader::new(Arc::clone(&rt), dir)) as Arc<dyn Loader>,
                Duration::from_secs(60),
            )
            .unwrap();
        }
        Some(m)
    }

    #[test]
    fn predict_latest_and_specific() {
        let Some(m) = manager_with_classifier() else { return };
        let req = PredictRequest {
            model: "mlp_classifier".into(),
            version: None,
            input: Tensor::zeros(vec![2, 32]),
        };
        let resp = predict(m.as_ref(), &req).unwrap();
        assert_eq!(resp.model_version, 2); // latest
        assert_eq!(resp.outputs.len(), 2);
        assert_eq!(resp.outputs[0].as_f32().unwrap().shape(), &[2, 4]);

        let resp1 = predict(
            m.as_ref(),
            &PredictRequest { version: Some(1), ..req.clone() },
        )
        .unwrap();
        assert_eq!(resp1.model_version, 1);
    }

    #[test]
    fn predict_missing_model_errors() {
        let Some(m) = manager_with_classifier() else { return };
        let req = PredictRequest {
            model: "nope".into(),
            version: None,
            input: Tensor::zeros(vec![1, 32]),
        };
        assert!(predict(m.as_ref(), &req).is_err());
    }
}
