//! Inference APIs (paper §2.2).
//!
//! * [`example`] — the canonical example format (our `tf.Example`):
//!   typed feature maps with a binary codec and common-feature batch
//!   compression.
//! * [`predict`] — the low-level tensor API (mirrors `Session::Run`).
//! * [`classify`] / [`regress`] — the higher-level typed APIs over
//!   examples.
//! * [`logger`] — sampled inference logging (training/serving-skew
//!   detection hook).
//! * [`table`] — the "BananaFlow" platform: lookup-table servables,
//!   proving the manager treats servables as black boxes.
//! * [`null`] — zero-work servable isolating framework overhead (the
//!   §4 100k-qps methodology: "if those two layers are factored out").

pub mod classify;
pub mod example;
pub mod logger;
pub mod null;
pub mod predict;
pub mod regress;
pub mod table;
