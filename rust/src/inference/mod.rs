//! Inference APIs (paper §2.2).
//!
//! Every inference request addresses a model through a [`ModelSpec`]
//! — name plus an optional pinned version **or** version label
//! ("canary"/"stable", resolved by
//! [`crate::lifecycle::labels::LabelResolver`]) — and a named
//! signature of that model's servable.
//!
//! * [`example`] — the canonical example format (our `tf.Example`):
//!   typed feature maps with a binary codec and common-feature batch
//!   compression.
//! * [`predict`] — the low-level tensor API (mirrors `Session::Run`):
//!   named input tensors validated against the servable's declared
//!   signature, named outputs back.
//! * [`classify`] / [`regress`] — the higher-level typed APIs over
//!   examples.
//! * [`multi`] — MultiInference: one decoded example batch fanned out
//!   to several classify/regress heads in a single model run.
//! * [`logger`] — sampled inference logging (training/serving-skew
//!   detection hook).
//! * [`table`] — the "BananaFlow" platform: lookup-table servables,
//!   proving the manager treats servables as black boxes.
//! * [`null`] — zero-work servable isolating framework overhead (the
//!   §4 100k-qps methodology: "if those two layers are factored out").

pub mod classify;
pub mod example;
pub mod logger;
pub mod multi;
pub mod null;
pub mod predict;
pub mod regress;
pub mod table;

/// Which model (and which of its versions) a request addresses.
///
/// Resolution precedence: an explicit `version` pins exactly that
/// version; otherwise a `label` is resolved through the serving
/// stack's label map; otherwise the latest ready version serves.
/// Carrying **both** a version and a label is rejected at lookup time.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ModelSpec {
    pub name: String,
    pub version: Option<u64>,
    pub label: Option<String>,
}

impl ModelSpec {
    /// Latest ready version of `name`.
    pub fn latest(name: impl Into<String>) -> ModelSpec {
        ModelSpec { name: name.into(), version: None, label: None }
    }

    /// Exactly version `version` of `name`.
    pub fn at_version(name: impl Into<String>, version: u64) -> ModelSpec {
        ModelSpec { name: name.into(), version: Some(version), label: None }
    }

    /// Whichever version currently carries `label`.
    pub fn with_label(name: impl Into<String>, label: impl Into<String>) -> ModelSpec {
        ModelSpec { name: name.into(), version: None, label: Some(label.into()) }
    }

    /// Legacy constructor mirroring the old `(model, Option<version>)`
    /// addressing.
    pub fn named(name: impl Into<String>, version: Option<u64>) -> ModelSpec {
        ModelSpec { name: name.into(), version, label: None }
    }
}

impl std::fmt::Display for ModelSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name)?;
        if let Some(v) = self.version {
            write!(f, ":{v}")?;
        }
        if let Some(l) = &self.label {
            write!(f, "@{l}")?;
        }
        Ok(())
    }
}
