//! Sampled inference logging (§2.2): "The handlers are equipped with
//! logging capability, which is useful for debugging, detecting
//! training/serving skew, and validating model changes."
//!
//! Entries land in a bounded in-memory ring (drainable by an exporter);
//! the canary example uses the log to compare v1-vs-v2 predictions on
//! teed traffic.

use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One logged inference.
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    pub model: String,
    pub version: u64,
    /// Caller-provided digest of the request (e.g. input checksum).
    pub request_digest: u64,
    /// Caller-provided digest/summary of the response (e.g. argmax).
    pub response_digest: u64,
}

/// Sampling request/response logger.
pub struct RequestLogger {
    sample_rate: f64,
    capacity: usize,
    ring: Mutex<(VecDeque<LogEntry>, Rng)>,
    seen: AtomicU64,
    logged: AtomicU64,
}

impl RequestLogger {
    /// Log ~`sample_rate` of requests, keeping the most recent
    /// `capacity` entries.
    pub fn new(sample_rate: f64, capacity: usize, seed: u64) -> Self {
        RequestLogger {
            sample_rate,
            capacity,
            ring: Mutex::new((VecDeque::with_capacity(capacity), Rng::new(seed))),
            seen: AtomicU64::new(0),
            logged: AtomicU64::new(0),
        }
    }

    /// Offer an inference for logging; cheap when not sampled.
    pub fn observe(&self, model: &str, version: u64, request_digest: u64, response_digest: u64) {
        self.seen.fetch_add(1, Ordering::Relaxed);
        if self.sample_rate <= 0.0 {
            return;
        }
        let mut g = self.ring.lock().unwrap();
        let sampled = self.sample_rate >= 1.0 || g.1.chance(self.sample_rate);
        if !sampled {
            return;
        }
        if g.0.len() == self.capacity {
            g.0.pop_front();
        }
        g.0.push_back(LogEntry {
            model: model.to_string(),
            version,
            request_digest,
            response_digest,
        });
        self.logged.fetch_add(1, Ordering::Relaxed);
    }

    /// Drain everything logged so far.
    pub fn drain(&self) -> Vec<LogEntry> {
        self.ring.lock().unwrap().0.drain(..).collect()
    }

    /// Entries currently held (without draining).
    pub fn snapshot(&self) -> Vec<LogEntry> {
        self.ring.lock().unwrap().0.iter().cloned().collect()
    }

    pub fn seen(&self) -> u64 {
        self.seen.load(Ordering::Relaxed)
    }

    pub fn logged(&self) -> u64 {
        self.logged.load(Ordering::Relaxed)
    }
}

/// FNV-1a digest helper for request/response summaries.
pub fn digest_f32s(xs: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in xs {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_sampling_logs_everything() {
        let l = RequestLogger::new(1.0, 100, 0);
        for i in 0..10 {
            l.observe("m", 1, i, i * 2);
        }
        assert_eq!(l.seen(), 10);
        assert_eq!(l.logged(), 10);
        let entries = l.drain();
        assert_eq!(entries.len(), 10);
        assert_eq!(entries[3].request_digest, 3);
        assert!(l.drain().is_empty());
    }

    #[test]
    fn zero_sampling_logs_nothing() {
        let l = RequestLogger::new(0.0, 100, 0);
        for i in 0..100 {
            l.observe("m", 1, i, i);
        }
        assert_eq!(l.seen(), 100);
        assert_eq!(l.logged(), 0);
    }

    #[test]
    fn partial_sampling_is_roughly_proportional() {
        let l = RequestLogger::new(0.2, 100_000, 7);
        for i in 0..10_000 {
            l.observe("m", 1, i, i);
        }
        let rate = l.logged() as f64 / l.seen() as f64;
        assert!((rate - 0.2).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn ring_is_bounded_keeping_recent() {
        let l = RequestLogger::new(1.0, 5, 0);
        for i in 0..20 {
            l.observe("m", 1, i, i);
        }
        let entries = l.snapshot();
        assert_eq!(entries.len(), 5);
        assert_eq!(entries[0].request_digest, 15);
        assert_eq!(entries[4].request_digest, 19);
    }

    #[test]
    fn digest_distinguishes_inputs() {
        assert_ne!(digest_f32s(&[1.0, 2.0]), digest_f32s(&[2.0, 1.0]));
        assert_eq!(digest_f32s(&[1.0, 2.0]), digest_f32s(&[1.0, 2.0]));
    }
}
