//! The Regression API (§2.2): typed, example-based inference for
//! signatures exported with the `regress` method.

use super::example::Example;
use super::predict::{run_example_signature, HandleSource};
use super::ModelSpec;
use crate::base::error::ErrorKind;
use crate::runtime::pjrt::OutTensor;
use crate::serving::{DirectRunner, RunOptions, Runner};
use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct RegressRequest {
    pub spec: ModelSpec,
    /// Signature to invoke; `""` means the default serving signature.
    pub signature: String,
    pub examples: Vec<Example>,
}

impl RegressRequest {
    /// Legacy constructor: default signature, (model, version?) addressing.
    pub fn simple(
        model: impl Into<String>,
        version: Option<u64>,
        examples: Vec<Example>,
    ) -> Self {
        RegressRequest {
            spec: ModelSpec::named(model, version),
            signature: String::new(),
            examples,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RegressResponse {
    pub model_version: u64,
    /// One predicted value per example.
    pub values: Vec<f32>,
}

/// Extract per-example regression values from a signature's named
/// outputs (the sole rank-1 f32 output; two candidates is an error,
/// never a silent first-match binding).
pub(crate) fn regression_values(
    sig_name: &str,
    named: &[(String, OutTensor)],
    n: usize,
) -> Result<Vec<f32>> {
    let values = super::classify::sole_matching_output(
        sig_name,
        named,
        "f32 [batch] value",
        |t| t.as_f32().map(|t| t.rank() == 1).unwrap_or(false),
    )?
    .as_f32()?;
    if values.len() < n {
        bail!(
            "signature '{sig_name}': value output covers {} rows, want {n}",
            values.len()
        );
    }
    Ok(values.data()[..n].to_vec())
}

/// Execute a regression request, with servable execution going through
/// `runner` (the serving path's cross-request batching seam).
pub fn regress_with(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &RegressRequest,
) -> Result<RegressResponse> {
    regress_with_opts(handles, runner, req, &RunOptions::default())
}

/// [`regress_with`] plus per-request [`RunOptions`] (deadline
/// propagation).
pub fn regress_with_opts(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &RegressRequest,
    opts: &RunOptions,
) -> Result<RegressResponse> {
    if req.examples.is_empty() {
        return Err(ErrorKind::InvalidArgument.err("regress: empty example list"));
    }
    let (model_version, values) = run_example_signature(
        handles,
        runner,
        opts,
        &req.spec,
        &req.signature,
        "regress",
        &req.examples,
        |sig_name, named| regression_values(sig_name, named, req.examples.len()),
    )?;
    Ok(RegressResponse { model_version, values })
}

/// [`regress_with`] using unbatched direct execution.
pub fn regress(handles: &dyn HandleSource, req: &RegressRequest) -> Result<RegressResponse> {
    regress_with(handles, &DirectRunner, req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::loader::Loader;
    use crate::base::servable::ServableId;
    use crate::inference::example::Feature;
    use crate::lifecycle::basic_manager::BasicManager;
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};
    use crate::runtime::hlo_servable::HloLoader;
    use crate::runtime::pjrt::XlaRuntime;
    use std::sync::Arc;
    use std::time::Duration;

    fn manager() -> Option<Arc<BasicManager>> {
        if !artifacts_available() {
            return None;
        }
        let rt = XlaRuntime::shared().unwrap();
        let m = BasicManager::with_defaults();
        let dir = default_artifacts_root().join("mlp_regressor").join("2");
        m.load_and_wait(
            ServableId::new("mlp_regressor", 2),
            Arc::new(HloLoader::new(rt, dir)) as Arc<dyn Loader>,
            Duration::from_secs(60),
        )
        .unwrap();
        Some(m)
    }

    /// Pseudo-gaussian row (in-distribution for the trained model).
    fn example(seed: u64, scale: f32) -> Example {
        let mut rng = crate::util::rng::Rng::new(seed);
        let x: Vec<f32> = (0..32).map(|_| scale * rng.normal() as f32).collect();
        Example::new().with("x", Feature::Floats(x))
    }

    #[test]
    fn regress_predicts_norm_like_values() {
        let Some(m) = manager() else { return };
        // Target is tanh(x0) + 0.5*x1*x2; predictions must correlate.
        let examples: Vec<Example> = (0..64).map(|i| example(i, 1.0)).collect();
        let targets: Vec<f32> = examples
            .iter()
            .map(|e| {
                let x = e.floats("x").unwrap();
                x[0].tanh() + 0.5 * x[1] * x[2]
            })
            .collect();
        let resp = regress(
            m.as_ref(),
            &RegressRequest::simple("mlp_regressor", None, examples),
        )
        .unwrap();
        assert_eq!(resp.values.len(), 64);
        assert_eq!(resp.model_version, 2);
        // Pearson correlation between prediction and target.
        let n = 64.0f32;
        let (mp, mt) = (
            resp.values.iter().sum::<f32>() / n,
            targets.iter().sum::<f32>() / n,
        );
        let cov: f32 = resp
            .values
            .iter()
            .zip(&targets)
            .map(|(p, t)| (p - mp) * (t - mt))
            .sum();
        let vp: f32 = resp.values.iter().map(|p| (p - mp) * (p - mp)).sum();
        let vt: f32 = targets.iter().map(|t| (t - mt) * (t - mt)).sum();
        let r = cov / (vp.sqrt() * vt.sqrt());
        assert!(r > 0.6, "prediction/target correlation too low: r={r}");
    }

    #[test]
    fn regress_rejects_classifier() {
        let Some(m) = manager() else { return };
        // mlp_classifier isn't even loaded here: missing model error.
        assert!(regress(
            m.as_ref(),
            &RegressRequest::simple("mlp_classifier", None, vec![example(0, 1.0)]),
        )
        .is_err());
    }
}
