//! The canonical example format — our `tf.Example` (§2.2).
//!
//! "We have co-designed a canonical data format for examples … we
//! nevertheless do our best to optimize our standard example
//! representation (e.g. compressing away features common to a batch of
//! examples)."
//!
//! An [`Example`] is a name → [`Feature`] map. The wire format is a
//! hand-rolled length-prefixed binary codec ([`Example::encode`]);
//! batches use [`CompressedBatch`], which stores features shared by
//! *every* example exactly once.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A typed feature value.
#[derive(Debug, Clone, PartialEq)]
pub enum Feature {
    Floats(Vec<f32>),
    Ints(Vec<i64>),
    Bytes(Vec<u8>),
}

impl Feature {
    fn kind(&self) -> u8 {
        match self {
            Feature::Floats(_) => 0,
            Feature::Ints(_) => 1,
            Feature::Bytes(_) => 2,
        }
    }
}

/// One example: an ordered feature map.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Example {
    pub features: BTreeMap<String, Feature>,
}

// --- wire helpers -----------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    let end = *pos + 4;
    if end > buf.len() {
        bail!("truncated u32 at {pos}");
    }
    let v = u32::from_le_bytes(buf[*pos..end].try_into().unwrap());
    *pos = end;
    Ok(v)
}

impl Example {
    pub fn new() -> Self {
        Example::default()
    }

    /// Builder-style insert.
    pub fn with(mut self, name: &str, feature: Feature) -> Self {
        self.features.insert(name.to_string(), feature);
        self
    }

    pub fn floats(&self, name: &str) -> Result<&[f32]> {
        match self.features.get(name) {
            Some(Feature::Floats(v)) => Ok(v),
            Some(_) => bail!("feature '{name}' is not float"),
            None => bail!("feature '{name}' missing"),
        }
    }

    pub fn ints(&self, name: &str) -> Result<&[i64]> {
        match self.features.get(name) {
            Some(Feature::Ints(v)) => Ok(v),
            Some(_) => bail!("feature '{name}' is not int"),
            None => bail!("feature '{name}' missing"),
        }
    }

    // ------------------------------------------------------------ codec

    /// Binary encoding: `[n_features] ( [name_len][name][kind][len][payload] )*`.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, self.features.len() as u32);
        for (name, feature) in &self.features {
            put_u32(&mut out, name.len() as u32);
            out.extend_from_slice(name.as_bytes());
            out.push(feature.kind());
            match feature {
                Feature::Floats(v) => {
                    put_u32(&mut out, v.len() as u32);
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Feature::Ints(v) => {
                    put_u32(&mut out, v.len() as u32);
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Feature::Bytes(v) => {
                    put_u32(&mut out, v.len() as u32);
                    out.extend_from_slice(v);
                }
            }
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<Example> {
        let mut pos = 0usize;
        let ex = Self::decode_at(buf, &mut pos)?;
        if pos != buf.len() {
            bail!("trailing bytes after example");
        }
        Ok(ex)
    }

    fn decode_at(buf: &[u8], pos: &mut usize) -> Result<Example> {
        let n = get_u32(buf, pos)? as usize;
        if n > 1_000_000 {
            bail!("implausible feature count {n}");
        }
        let mut features = BTreeMap::new();
        for _ in 0..n {
            let name_len = get_u32(buf, pos)? as usize;
            let name_end = *pos + name_len;
            if name_end > buf.len() {
                bail!("truncated name");
            }
            let name = std::str::from_utf8(&buf[*pos..name_end])
                .map_err(|_| anyhow!("name not utf-8"))?
                .to_string();
            *pos = name_end;
            let kind = *buf.get(*pos).ok_or_else(|| anyhow!("truncated kind"))?;
            *pos += 1;
            let len = get_u32(buf, pos)? as usize;
            let feature = match kind {
                0 => {
                    let end = *pos + len * 4;
                    if end > buf.len() {
                        bail!("truncated floats");
                    }
                    let v = buf[*pos..end]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    *pos = end;
                    Feature::Floats(v)
                }
                1 => {
                    let end = *pos + len * 8;
                    if end > buf.len() {
                        bail!("truncated ints");
                    }
                    let v = buf[*pos..end]
                        .chunks_exact(8)
                        .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    *pos = end;
                    Feature::Ints(v)
                }
                2 => {
                    let end = *pos + len;
                    if end > buf.len() {
                        bail!("truncated bytes");
                    }
                    let v = buf[*pos..end].to_vec();
                    *pos = end;
                    Feature::Bytes(v)
                }
                k => bail!("unknown feature kind {k}"),
            };
            features.insert(name, feature);
        }
        Ok(Example { features })
    }
}

/// A batch of examples with features common to *all* members hoisted
/// out and stored once (the paper's batch compression).
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedBatch {
    /// Features identical across every example.
    pub common: Example,
    /// Per-example residual features.
    pub rows: Vec<Example>,
}

impl CompressedBatch {
    /// Compress by hoisting features that are identical in all examples.
    pub fn compress(examples: &[Example]) -> CompressedBatch {
        let mut common = Example::new();
        if let Some(first) = examples.first() {
            for (name, feature) in &first.features {
                if examples
                    .iter()
                    .all(|ex| ex.features.get(name) == Some(feature))
                {
                    common.features.insert(name.clone(), feature.clone());
                }
            }
        }
        let rows = examples
            .iter()
            .map(|ex| {
                let mut r = Example::new();
                for (name, feature) in &ex.features {
                    if !common.features.contains_key(name) {
                        r.features.insert(name.clone(), feature.clone());
                    }
                }
                r
            })
            .collect();
        CompressedBatch { common, rows }
    }

    /// Reconstruct the full examples.
    pub fn decompress(&self) -> Vec<Example> {
        self.rows
            .iter()
            .map(|row| {
                let mut ex = self.common.clone();
                for (name, feature) in &row.features {
                    ex.features.insert(name.clone(), feature.clone());
                }
                ex
            })
            .collect()
    }

    /// Wire encoding: `[common][n_rows][row]*` with length prefixes.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let c = self.common.encode();
        put_u32(&mut out, c.len() as u32);
        out.extend_from_slice(&c);
        put_u32(&mut out, self.rows.len() as u32);
        for row in &self.rows {
            let r = row.encode();
            put_u32(&mut out, r.len() as u32);
            out.extend_from_slice(&r);
        }
        out
    }

    pub fn decode(buf: &[u8]) -> Result<CompressedBatch> {
        let mut pos = 0usize;
        let clen = get_u32(buf, &mut pos)? as usize;
        let common = Example::decode(
            buf.get(pos..pos + clen).ok_or_else(|| anyhow!("truncated common"))?,
        )?;
        pos += clen;
        let n = get_u32(buf, &mut pos)? as usize;
        if n > 10_000_000 {
            bail!("implausible row count {n}");
        }
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let rlen = get_u32(buf, &mut pos)? as usize;
            rows.push(Example::decode(
                buf.get(pos..pos + rlen).ok_or_else(|| anyhow!("truncated row"))?,
            )?);
            pos += rlen;
        }
        if pos != buf.len() {
            bail!("trailing bytes after batch");
        }
        Ok(CompressedBatch { common, rows })
    }

    pub fn encoded_len(&self) -> usize {
        self.encode().len()
    }
}

/// Extract feature `name` from each example into a dense `(B, D)`
/// tensor (the classify/regress APIs' input path). Rows are written
/// straight into pooled tensor storage — one allocation (or none, on a
/// pool hit), no intermediate `Vec`.
pub fn examples_to_tensor(
    examples: &[Example],
    feature: &str,
    dim: usize,
) -> Result<crate::base::tensor::Tensor> {
    use crate::base::error::ErrorKind;
    let mut rows = Vec::with_capacity(examples.len());
    for (i, ex) in examples.iter().enumerate() {
        // Malformed examples are the caller's fault: carry
        // InvalidArgument so the gateway answers 400, not 500.
        let f = ex
            .floats(feature)
            .map_err(|e| ErrorKind::InvalidArgument.err(format!("example {i}: {e}")))?;
        if f.len() != dim {
            return Err(ErrorKind::InvalidArgument.err(format!(
                "example {i}: feature '{feature}' has {} values, want {dim}",
                f.len()
            )));
        }
        rows.push(f);
    }
    Ok(crate::base::tensor::Tensor::build_with(
        vec![examples.len(), dim],
        &crate::util::pool::BufferPool::global(),
        |buf| {
            for (i, row) in rows.iter().enumerate() {
                buf[i * dim..(i + 1) * dim].copy_from_slice(row);
            }
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn sample_example() -> Example {
        Example::new()
            .with("x", Feature::Floats(vec![1.5, -2.0, 3.25]))
            .with("id", Feature::Ints(vec![42]))
            .with("tag", Feature::Bytes(b"hello".to_vec()))
    }

    #[test]
    fn encode_decode_roundtrip() {
        let ex = sample_example();
        let buf = ex.encode();
        assert_eq!(Example::decode(&buf).unwrap(), ex);
    }

    #[test]
    fn empty_example_roundtrip() {
        let ex = Example::new();
        assert_eq!(Example::decode(&ex.encode()).unwrap(), ex);
    }

    #[test]
    fn decode_rejects_corruption() {
        let buf = sample_example().encode();
        assert!(Example::decode(&buf[..buf.len() - 1]).is_err());
        let mut trailing = buf.clone();
        trailing.push(0);
        assert!(Example::decode(&trailing).is_err());
        assert!(Example::decode(&[]).is_err());
        // absurd feature count
        assert!(Example::decode(&u32::MAX.to_le_bytes()).is_err());
    }

    #[test]
    fn typed_accessors() {
        let ex = sample_example();
        assert_eq!(ex.floats("x").unwrap(), &[1.5, -2.0, 3.25]);
        assert_eq!(ex.ints("id").unwrap(), &[42]);
        assert!(ex.floats("id").is_err());
        assert!(ex.floats("missing").is_err());
    }

    #[test]
    fn compression_hoists_common_features() {
        let mk = |x: f32| {
            Example::new()
                .with("x", Feature::Floats(vec![x]))
                .with("model_cfg", Feature::Bytes(vec![9; 100]))
        };
        let examples: Vec<Example> = (0..10).map(|i| mk(i as f32)).collect();
        let batch = CompressedBatch::compress(&examples);
        assert!(batch.common.features.contains_key("model_cfg"));
        assert!(!batch.rows[0].features.contains_key("model_cfg"));
        assert_eq!(batch.decompress(), examples);

        // Compression actually saves bytes vs naive concatenation.
        let naive: usize = examples.iter().map(|e| e.encode().len()).sum();
        assert!(
            batch.encoded_len() < naive / 2,
            "compressed {} vs naive {naive}",
            batch.encoded_len()
        );
    }

    #[test]
    fn compression_keeps_differing_features_per_row() {
        let a = Example::new().with("x", Feature::Floats(vec![1.0]));
        let b = Example::new().with("x", Feature::Floats(vec![2.0]));
        let batch = CompressedBatch::compress(&[a.clone(), b.clone()]);
        assert!(batch.common.features.is_empty());
        assert_eq!(batch.decompress(), vec![a, b]);
    }

    #[test]
    fn compressed_batch_codec_roundtrip() {
        let examples: Vec<Example> = (0..5)
            .map(|i| {
                Example::new()
                    .with("x", Feature::Floats(vec![i as f32; 4]))
                    .with("shared", Feature::Ints(vec![7]))
            })
            .collect();
        let batch = CompressedBatch::compress(&examples);
        let decoded = CompressedBatch::decode(&batch.encode()).unwrap();
        assert_eq!(decoded, batch);
        assert_eq!(decoded.decompress(), examples);
    }

    #[test]
    fn examples_to_tensor_builds_batch() {
        let examples: Vec<Example> = (0..3)
            .map(|i| Example::new().with("x", Feature::Floats(vec![i as f32, 0.5])))
            .collect();
        let t = examples_to_tensor(&examples, "x", 2).unwrap();
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.row(2), &[2.0, 0.5]);
        assert!(examples_to_tensor(&examples, "x", 3).is_err());
        assert!(examples_to_tensor(&examples, "y", 2).is_err());
    }

    #[test]
    fn property_roundtrip_random_examples() {
        forall::<(u64, u64), _>("example codec roundtrip", |(seed, nf)| {
            let mut rng = Rng::new(*seed);
            let mut ex = Example::new();
            for i in 0..(nf % 6) {
                let name = format!("f{i}");
                let feature = match rng.next_below(3) {
                    0 => Feature::Floats(
                        (0..rng.next_below(8)).map(|_| rng.next_f32()).collect(),
                    ),
                    1 => Feature::Ints(
                        (0..rng.next_below(8)).map(|_| rng.next_u64() as i64).collect(),
                    ),
                    _ => Feature::Bytes(
                        (0..rng.next_below(16)).map(|_| rng.next_u64() as u8).collect(),
                    ),
                };
                ex.features.insert(name, feature);
            }
            Example::decode(&ex.encode()).map(|d| d == ex).unwrap_or(false)
        });
    }
}
