//! [`NullServable`]: a zero-work servable.
//!
//! §4's 100k-qps/core figure measures TensorFlow-Serving *itself* — "if
//! those two layers [RPC and TensorFlow] are factored out". The null
//! servable factors out the model layer: handle lookup, refcounting,
//! batching and dispatch all run for real, but "inference" is a counter
//! bump. `benches/bench_throughput.rs` (experiment T1) serves these.

use crate::base::loader::{FnLoader, Loader, ResourceEstimate};
use crate::base::servable::ServableBox;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Does nothing, quickly.
pub struct NullServable {
    calls: AtomicU64,
}

impl NullServable {
    pub fn new() -> Self {
        NullServable { calls: AtomicU64::new(0) }
    }

    /// The "inference": count and echo the input size.
    #[inline]
    pub fn run(&self, input_rows: usize) -> usize {
        self.calls.fetch_add(1, Ordering::Relaxed);
        input_rows
    }

    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }
}

impl Default for NullServable {
    fn default() -> Self {
        Self::new()
    }
}

/// Loader producing a fresh [`NullServable`].
pub fn null_loader() -> Arc<dyn Loader> {
    Arc::new(FnLoader::new(ResourceEstimate::default(), "null", || {
        Ok(Arc::new(NullServable::new()) as ServableBox)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::servable::ServableId;
    use crate::lifecycle::basic_manager::{BasicManager, VersionRequest};
    use std::time::Duration;

    #[test]
    fn counts_calls() {
        let s = NullServable::new();
        assert_eq!(s.run(4), 4);
        assert_eq!(s.run(1), 1);
        assert_eq!(s.calls(), 2);
    }

    #[test]
    fn serves_through_manager() {
        let m = BasicManager::with_defaults();
        m.load_and_wait(
            ServableId::new("null", 1),
            null_loader(),
            Duration::from_secs(5),
        )
        .unwrap();
        let h = m.handle::<NullServable>("null", VersionRequest::Latest).unwrap();
        for _ in 0..100 {
            h.run(1);
        }
        assert_eq!(h.calls(), 100);
    }
}
