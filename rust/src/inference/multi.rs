//! MultiInference (§2.2 / TF-Serving's `MultiInferenceRequest`): run
//! several classify/regress heads over **one** decoded example batch.
//!
//! The examples are decoded into a feature tensor once and the
//! servable executes once — no per-head re-decode or re-run. Each
//! head selects its tensors from the shared output tuple as view
//! clones (PR 1's view tensors); materializing the typed
//! `HeadResult` (per-example class/value vectors) then copies the
//! selected rows out, same as the single-head classify/regress APIs.

use super::classify::{classification_results, Classification};
use super::example::{examples_to_tensor, Example};
use super::predict::{name_outputs, recycle_out_tensors, sole_input, HandleSource};
use super::regress::regression_values;
use super::ModelSpec;
use crate::bail_kind;
use crate::base::error::ErrorKind;
use crate::serving::{DirectRunner, RunOptions, Runner};
use anyhow::Result;

/// Which typed API a task invokes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferenceMethod {
    Classify,
    Regress,
}

impl InferenceMethod {
    pub fn as_str(&self) -> &'static str {
        match self {
            InferenceMethod::Classify => "classify",
            InferenceMethod::Regress => "regress",
        }
    }
}

/// One head of a multi-inference request: a signature name plus the
/// method it must carry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferenceTask {
    pub signature: String,
    pub method: InferenceMethod,
}

impl InferenceTask {
    pub fn classify(signature: impl Into<String>) -> InferenceTask {
        InferenceTask { signature: signature.into(), method: InferenceMethod::Classify }
    }

    pub fn regress(signature: impl Into<String>) -> InferenceTask {
        InferenceTask { signature: signature.into(), method: InferenceMethod::Regress }
    }
}

/// One head's result.
#[derive(Debug, Clone, PartialEq)]
pub enum HeadResult {
    Classify { classes: Vec<i32>, log_probs: Vec<Vec<f32>> },
    Regress { values: Vec<f32> },
}

/// N heads over one shared example batch of one model.
#[derive(Debug, Clone)]
pub struct MultiInferenceRequest {
    pub spec: ModelSpec,
    pub tasks: Vec<InferenceTask>,
    pub examples: Vec<Example>,
}

#[derive(Debug, Clone)]
pub struct MultiInferenceResponse {
    pub model_version: u64,
    /// `(signature name, result)` per task, in request order.
    pub results: Vec<(String, HeadResult)>,
}

/// Execute a multi-inference request: decode once, run once (through
/// `runner`, so the shared execution merges with concurrent requests
/// when a [`crate::serving::SessionRegistry`] is in play), fan the
/// shared outputs out to every head.
pub fn multi_inference_with(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &MultiInferenceRequest,
) -> Result<MultiInferenceResponse> {
    multi_inference_with_opts(handles, runner, req, &RunOptions::default())
}

/// [`multi_inference_with`] plus per-request [`RunOptions`] (deadline
/// propagation).
pub fn multi_inference_with_opts(
    handles: &dyn HandleSource,
    runner: &dyn Runner,
    req: &MultiInferenceRequest,
    opts: &RunOptions,
) -> Result<MultiInferenceResponse> {
    if req.tasks.is_empty() {
        return Err(ErrorKind::InvalidArgument.err("multi_inference: empty task list"));
    }
    if req.examples.is_empty() {
        return Err(ErrorKind::InvalidArgument.err("multi_inference: empty example list"));
    }
    let handle = handles.hlo_handle(&req.spec)?;
    let spec = &handle.spec;

    // Validate every head up front: signature exists, method matches,
    // and all heads share the model's single input.
    let mut sigs = Vec::with_capacity(req.tasks.len());
    let mut shared_input: Option<&crate::runtime::artifacts::TensorInfo> = None;
    for task in &req.tasks {
        let (sig_name, sig) = spec.signature_def(&task.signature)?;
        if sig.method != task.method.as_str() {
            bail_kind!(
                ErrorKind::InvalidArgument,
                "model '{}' signature '{sig_name}' has method '{}', task wants '{}'",
                req.spec.name,
                sig.method,
                task.method.as_str()
            );
        }
        let input = sole_input(&req.spec.name, sig_name, sig)?;
        match shared_input {
            None => shared_input = Some(input),
            Some(prev) if prev == input => {}
            Some(prev) => bail_kind!(
                ErrorKind::InvalidArgument,
                "multi_inference: heads disagree on the shared input \
                 ('{}' vs '{}') — one decoded batch cannot feed both",
                prev.name,
                input.name
            ),
        }
        sigs.push((sig_name, sig));
    }
    let input_info = shared_input.expect("at least one task");

    // Decode the example batch ONCE, run the servable ONCE. The
    // feature tensor recycles whether or not the run succeeded.
    let input = examples_to_tensor(&req.examples, &input_info.name, spec.input_dim)?;
    let run = runner.run_opts(&handle, &input, opts);
    input.recycle_into(&crate::util::pool::BufferPool::global());
    let outputs = run?;

    // Fan out: each head selects its outputs from the shared tuple
    // (view clones; the typed result rows copy out below).
    let n = req.examples.len();
    let results = req
        .tasks
        .iter()
        .zip(&sigs)
        .map(|(task, (sig_name, sig))| {
            let named = name_outputs(spec, sig_name, sig, &outputs)?;
            let result = match task.method {
                InferenceMethod::Classify => {
                    let results = classification_results(sig_name, &named, n)?;
                    HeadResult::Classify {
                        classes: results.iter().map(|c| c.class).collect(),
                        log_probs: results.into_iter().map(|c| c.log_probs).collect(),
                    }
                }
                InferenceMethod::Regress => {
                    HeadResult::Regress { values: regression_values(sig_name, &named, n)? }
                }
            };
            Ok((sig_name.to_string(), result))
        })
        .collect::<Result<Vec<_>>>();
    // Typed results are owned copies: hand the shared output storage
    // back to the pools (error paths included).
    recycle_out_tensors(outputs);
    Ok(MultiInferenceResponse { model_version: handle.id().version, results: results? })
}

/// [`multi_inference_with`] using unbatched direct execution.
pub fn multi_inference(
    handles: &dyn HandleSource,
    req: &MultiInferenceRequest,
) -> Result<MultiInferenceResponse> {
    multi_inference_with(handles, &DirectRunner, req)
}

/// Re-shape a classify-style head back into per-example results
/// (convenience for callers migrating from the single-head API).
pub fn classifications(head: &HeadResult) -> Option<Vec<Classification>> {
    match head {
        HeadResult::Classify { classes, log_probs } => Some(
            classes
                .iter()
                .zip(log_probs)
                .map(|(&class, lp)| Classification { class, log_probs: lp.clone() })
                .collect(),
        ),
        HeadResult::Regress { .. } => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::servable::ServableId;
    use crate::inference::example::Feature;
    use crate::lifecycle::basic_manager::BasicManager;
    use crate::runtime::artifacts::ArtifactSpec;
    use crate::runtime::hlo_servable::synthetic_loader;
    use std::sync::Arc;
    use std::time::Duration;

    fn manager() -> Arc<BasicManager> {
        let m = BasicManager::with_defaults();
        m.load_and_wait(
            ServableId::new("multi", 3),
            synthetic_loader(ArtifactSpec::synthetic_multi_head("multi", 3, 8, 4)),
            Duration::from_secs(10),
        )
        .unwrap();
        m
    }

    fn examples(n: usize) -> Vec<Example> {
        (0..n)
            .map(|i| {
                Example::new().with(
                    "x",
                    Feature::Floats((0..8).map(|j| ((i * 5 + j) as f32).cos()).collect()),
                )
            })
            .collect()
    }

    #[test]
    fn two_heads_over_one_batch() {
        let m = manager();
        let resp = multi_inference(
            m.as_ref(),
            &MultiInferenceRequest {
                spec: ModelSpec::latest("multi"),
                tasks: vec![InferenceTask::classify("classify"), InferenceTask::regress("regress")],
                examples: examples(5),
            },
        )
        .unwrap();
        assert_eq!(resp.model_version, 3);
        assert_eq!(resp.results.len(), 2);
        let (cname, chead) = &resp.results[0];
        assert_eq!(cname, "classify");
        match chead {
            HeadResult::Classify { classes, log_probs } => {
                assert_eq!(classes.len(), 5);
                assert_eq!(log_probs.len(), 5);
                for (c, lp) in classes.iter().zip(log_probs) {
                    assert_eq!(lp.len(), 4);
                    assert!((0..4).contains(c));
                    let p: f32 = lp.iter().map(|x| x.exp()).sum();
                    assert!((p - 1.0).abs() < 1e-4);
                }
                assert_eq!(classifications(chead).unwrap().len(), 5);
            }
            other => panic!("unexpected {other:?}"),
        }
        let (rname, rhead) = &resp.results[1];
        assert_eq!(rname, "regress");
        match rhead {
            HeadResult::Regress { values } => assert_eq!(values.len(), 5),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn head_results_match_single_head_apis() {
        // The fan-out must agree with calling classify/regress alone.
        let m = manager();
        let exs = examples(3);
        let multi = multi_inference(
            m.as_ref(),
            &MultiInferenceRequest {
                spec: ModelSpec::latest("multi"),
                tasks: vec![InferenceTask::classify("classify"), InferenceTask::regress("regress")],
                examples: exs.clone(),
            },
        )
        .unwrap();
        let solo_c = crate::inference::classify::classify(
            m.as_ref(),
            &crate::inference::classify::ClassifyRequest {
                spec: ModelSpec::latest("multi"),
                signature: "classify".into(),
                examples: exs.clone(),
            },
        )
        .unwrap();
        let solo_r = crate::inference::regress::regress(
            m.as_ref(),
            &crate::inference::regress::RegressRequest {
                spec: ModelSpec::latest("multi"),
                signature: "regress".into(),
                examples: exs,
            },
        )
        .unwrap();
        match &multi.results[0].1 {
            HeadResult::Classify { classes, .. } => {
                let solo: Vec<i32> = solo_c.results.iter().map(|c| c.class).collect();
                assert_eq!(classes, &solo);
            }
            other => panic!("unexpected {other:?}"),
        }
        match &multi.results[1].1 {
            HeadResult::Regress { values } => assert_eq!(values, &solo_r.values),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn validation_errors() {
        let m = manager();
        // Empty tasks / examples.
        assert!(multi_inference(
            m.as_ref(),
            &MultiInferenceRequest {
                spec: ModelSpec::latest("multi"),
                tasks: vec![],
                examples: examples(1),
            },
        )
        .is_err());
        assert!(multi_inference(
            m.as_ref(),
            &MultiInferenceRequest {
                spec: ModelSpec::latest("multi"),
                tasks: vec![InferenceTask::classify("classify")],
                examples: vec![],
            },
        )
        .is_err());
        // Method mismatch: regress task against the classify signature.
        let err = multi_inference(
            m.as_ref(),
            &MultiInferenceRequest {
                spec: ModelSpec::latest("multi"),
                tasks: vec![InferenceTask::regress("classify")],
                examples: examples(1),
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("classify") && err.contains("regress"), "{err}");
        // Unknown signature.
        let err = multi_inference(
            m.as_ref(),
            &MultiInferenceRequest {
                spec: ModelSpec::latest("multi"),
                tasks: vec![InferenceTask::classify("ghost")],
                examples: examples(1),
            },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("ghost"), "{err}");
    }
}
