//! The "BananaFlow" platform: lookup-table servables (§2.1).
//!
//! "Servables do not need to be machine learning models at all, e.g.
//! they could be lookup tables that encode feature transformations."
//! This second platform proves the lifecycle chain is genuinely
//! black-box: the same Sources/Routers/Managers serve HLO models and
//! these tables side by side (see the Figure-1 integration test).

use crate::base::loader::{Loader, ResourceEstimate};
use crate::base::servable::ServableBox;
use crate::lifecycle::source_adapter::FnSourceAdapter;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// An embedding/feature lookup table.
pub struct TableServable {
    pub name: String,
    pub version: u64,
    entries: HashMap<String, Vec<f32>>,
}

impl TableServable {
    pub fn from_entries(
        name: &str,
        version: u64,
        entries: HashMap<String, Vec<f32>>,
    ) -> Self {
        TableServable { name: name.to_string(), version, entries }
    }

    /// Parse the `table.json` artifact.
    pub fn from_json(json: &Json) -> Result<TableServable> {
        if json.get("platform").and_then(|v| v.as_str()) != Some("table") {
            bail!("not a table artifact");
        }
        let name = json
            .get("model_name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("table: missing model_name"))?
            .to_string();
        let version = json
            .get("version")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow!("table: missing version"))?;
        let entries = json
            .get("entries")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow!("table: missing entries"))?
            .iter()
            .map(|(k, v)| {
                let vec = v
                    .as_arr()
                    .ok_or_else(|| anyhow!("table entry '{k}' not an array"))?
                    .iter()
                    .map(|x| x.as_f64().map(|f| f as f32))
                    .collect::<Option<Vec<f32>>>()
                    .ok_or_else(|| anyhow!("table entry '{k}' not numeric"))?;
                Ok((k.clone(), vec))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        Ok(TableServable { name, version, entries })
    }

    pub fn lookup(&self, key: &str) -> Option<&[f32]> {
        self.entries.get(key).map(|v| v.as_slice())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn ram_bytes(&self) -> u64 {
        self.entries
            .iter()
            .map(|(k, v)| (k.len() + v.len() * 4 + 64) as u64)
            .sum()
    }
}

/// Loads a table version from `<version_dir>/table.json`.
pub struct TableLoader {
    version_dir: PathBuf,
}

impl TableLoader {
    pub fn new(version_dir: PathBuf) -> Self {
        TableLoader { version_dir }
    }

    fn read(&self) -> Result<TableServable> {
        let json = Json::parse_file(&self.version_dir.join("table.json"))?;
        TableServable::from_json(&json)
    }
}

impl Loader for TableLoader {
    fn estimate(&self) -> Result<ResourceEstimate> {
        // Tables are small; estimate by parsing (cheap).
        Ok(ResourceEstimate::ram(self.read()?.ram_bytes()))
    }

    fn load(&self) -> Result<ServableBox> {
        Ok(Arc::new(self.read()?) as ServableBox)
    }

    fn describe(&self) -> String {
        format!("table:{}", self.version_dir.display())
    }
}

/// The BananaFlow Source Adapter: storage path → [`TableLoader`].
pub fn table_source_adapter() -> Arc<FnSourceAdapter<PathBuf, Arc<dyn Loader>>> {
    FnSourceAdapter::new(move |data: &crate::base::aspired::ServableData<PathBuf>| {
        let dir = data.payload.as_ref().unwrap().clone();
        Ok(Arc::new(TableLoader::new(dir)) as Arc<dyn Loader>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::base::servable::ServableId;
    use crate::lifecycle::basic_manager::{BasicManager, VersionRequest};
    use crate::runtime::artifacts::{artifacts_available, default_artifacts_root};
    use std::time::Duration;

    #[test]
    fn from_json_parses() {
        let json = Json::parse(
            r#"{"platform":"table","model_name":"t","version":1,
                "entries":{"a":[1,2],"b":[3]}}"#,
        )
        .unwrap();
        let t = TableServable::from_json(&json).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.lookup("a"), Some(&[1.0, 2.0][..]));
        assert_eq!(t.lookup("missing"), None);
        assert!(t.ram_bytes() > 0);
    }

    #[test]
    fn from_json_rejects_bad() {
        for bad in [
            r#"{"platform":"hlo"}"#,
            r#"{"platform":"table","version":1,"entries":{}}"#,
            r#"{"platform":"table","model_name":"t","version":1,"entries":{"a":["x"]}}"#,
        ] {
            assert!(TableServable::from_json(&Json::parse(bad).unwrap()).is_err());
        }
    }

    #[test]
    fn real_toy_table_loads_through_manager() {
        if !artifacts_available() {
            return;
        }
        let dir = default_artifacts_root().join("toy_table").join("1");
        let m = BasicManager::with_defaults();
        m.load_and_wait(
            ServableId::new("toy_table", 1),
            Arc::new(TableLoader::new(dir)),
            Duration::from_secs(10),
        )
        .unwrap();
        let h = m
            .handle::<TableServable>("toy_table", VersionRequest::Latest)
            .unwrap();
        assert_eq!(h.len(), 100);
        // aot.py: entries[i] = [i, i*i % 7]
        assert_eq!(h.lookup("3"), Some(&[3.0, 2.0][..]));
        assert_eq!(h.lookup("10"), Some(&[10.0, 2.0][..]));
    }
}
