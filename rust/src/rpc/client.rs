//! Synchronous RPC client + a small connection pool, with bounded
//! jittered retry for retryable failures ([`RetryPolicy`]).

use super::frame::{read_frame_into, write_framed};
use super::proto::{Request, Response};
use crate::base::error::ErrorKind;
use crate::util::rng::Rng;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

/// Client-side retry knobs. Retries apply only to failures the server
/// marked retryable ([`ErrorKind::is_retryable`]: shed load, drain,
/// unload races) and to transport errors (broken connection) — never
/// to `DeadlineExceeded` (the time budget is spent), validation
/// errors, or lookup misses, where a retry can't succeed (or, worse,
/// would double-execute a request whose first answer was lost).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retry).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub initial_backoff: Duration,
    /// Cap on any single backoff sleep.
    pub max_backoff: Duration,
    /// Jitter seed (full jitter: each sleep is uniform in
    /// `[0, backoff]`), so a thundering herd of shed clients spreads
    /// out instead of returning in lockstep.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            initial_backoff: Duration::from_millis(20),
            max_backoff: Duration::from_secs(1),
            seed: 0x5eed,
        }
    }
}

impl RetryPolicy {
    /// The jittered sleep before retry number `attempt` (1-based).
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .initial_backoff
            .saturating_mul(1u32 << (attempt - 1).min(16))
            .min(self.max_backoff);
        Duration::from_nanos(rng.next_below(exp.as_nanos().max(1) as u64))
    }
}

/// One connection; one request in flight at a time. Encode/decode
/// scratch buffers persist across calls, so a pooled connection issues
/// steady-state requests without per-call allocations.
pub struct RpcClient {
    stream: TcpStream,
    addr: String,
    encode_buf: Vec<u8>,
    payload_buf: Vec<u8>,
}

impl RpcClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            addr: addr.to_string(),
            encode_buf: Vec::new(),
            payload_buf: Vec::new(),
        })
    }

    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        let sock_addr: std::net::SocketAddr =
            addr.parse().with_context(|| format!("parse addr {addr}"))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            addr: addr.to_string(),
            encode_buf: Vec::new(),
            payload_buf: Vec::new(),
        })
    }

    /// Issue one request and wait for the response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        // Header reserved in the scratch buffer: one write syscall.
        req.encode_framed_into(&mut self.encode_buf);
        write_framed(&mut self.stream, &mut self.encode_buf)?;
        if !read_frame_into(&mut self.stream, &mut self.payload_buf)? {
            return Err(anyhow!("{}: connection closed mid-call", self.addr));
        }
        Response::decode(&self.payload_buf)
    }

    /// `call` + error-response unwrapping.
    pub fn call_ok(&mut self, req: &Request) -> Result<Response> {
        self.call(req)?.into_result()
    }

    /// `call_ok` with bounded, jittered retry. Server-side refusals
    /// retry only when their kind is retryable (shed, drain, unload
    /// race); transport failures reconnect first. Everything else —
    /// including `DeadlineExceeded` — returns immediately.
    pub fn call_retry(&mut self, req: &Request, policy: &RetryPolicy) -> Result<Response> {
        let mut rng = Rng::new(policy.seed);
        let mut attempt = 1u32;
        loop {
            let (err, transport) = match self.call(req) {
                Ok(resp) => match resp.into_result() {
                    Ok(resp) => return Ok(resp),
                    Err(e) => {
                        if !ErrorKind::of(&e).is_retryable() {
                            return Err(e);
                        }
                        (e, false)
                    }
                },
                Err(e) => (e, true),
            };
            if attempt >= policy.max_attempts {
                return Err(err.context(format!(
                    "giving up after {} attempt(s)",
                    policy.max_attempts
                )));
            }
            std::thread::sleep(policy.backoff(attempt, &mut rng));
            if transport {
                // The stream is suspect; replace it before retrying.
                match RpcClient::connect(&self.addr) {
                    Ok(fresh) => *self = fresh,
                    Err(_) => {} // next call() will surface the failure
                }
            }
            attempt += 1;
        }
    }

    /// Set a read deadline for subsequent calls (hedging uses this).
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
}

/// Pool of reusable connections per address.
#[derive(Default)]
pub struct ClientPool {
    idle: Mutex<HashMap<String, Vec<RpcClient>>>,
}

impl ClientPool {
    pub fn new() -> Self {
        ClientPool::default()
    }

    /// Check out a connection (reusing an idle one if available).
    pub fn get(&self, addr: &str) -> Result<RpcClient> {
        if let Some(c) = self
            .idle
            .lock()
            .unwrap()
            .get_mut(addr)
            .and_then(|v| v.pop())
        {
            return Ok(c);
        }
        RpcClient::connect(addr)
    }

    /// Return a healthy connection for reuse.
    pub fn put(&self, client: RpcClient) {
        let mut idle = self.idle.lock().unwrap();
        let v = idle.entry(client.addr.clone()).or_default();
        if v.len() < 16 {
            v.push(client);
        }
    }

    /// One-shot convenience: get → call → put (skip put on error).
    pub fn call(&self, addr: &str, req: &Request) -> Result<Response> {
        let mut client = self.get(addr)?;
        match client.call(req) {
            Ok(resp) => {
                self.put(client);
                Ok(resp)
            }
            Err(e) => Err(e), // drop broken connection
        }
    }

    pub fn idle_count(&self, addr: &str) -> usize {
        self.idle.lock().unwrap().get(addr).map_or(0, |v| v.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use std::sync::Arc;

    fn server() -> Arc<RpcServer> {
        RpcServer::start(
            "127.0.0.1:0",
            Arc::new(|req| match req {
                Request::Ping => Response::Pong,
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap()
    }

    #[test]
    fn call_ok_unwraps_errors() {
        let s = server();
        let mut c = RpcClient::connect(&s.addr().to_string()).unwrap();
        assert!(c.call_ok(&Request::Ping).is_ok());
        assert!(c.call_ok(&Request::Status).is_err());
    }

    #[test]
    fn pool_reuses_connections() {
        let s = server();
        let addr = s.addr().to_string();
        let pool = ClientPool::new();
        assert_eq!(pool.idle_count(&addr), 0);
        pool.call(&addr, &Request::Ping).unwrap();
        assert_eq!(pool.idle_count(&addr), 1);
        pool.call(&addr, &Request::Ping).unwrap();
        assert_eq!(pool.idle_count(&addr), 1); // reused, not grown
    }

    #[test]
    fn call_retry_retries_only_retryable_kinds() {
        use crate::base::error::ErrorKind;
        use std::sync::atomic::{AtomicU32, Ordering};

        let calls = Arc::new(AtomicU32::new(0));
        let c = Arc::clone(&calls);
        let s = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| {
                let n = c.fetch_add(1, Ordering::SeqCst);
                match req {
                    // Shed twice, then serve.
                    Request::Ping if n < 2 => Response::Error {
                        kind: ErrorKind::Unavailable,
                        message: "overloaded".into(),
                    },
                    Request::Ping => Response::Pong,
                    // Never retryable.
                    _ => Response::Error {
                        kind: ErrorKind::InvalidArgument,
                        message: "bad".into(),
                    },
                }
            }),
        )
        .unwrap();
        let policy = RetryPolicy {
            max_attempts: 3,
            initial_backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let mut client = RpcClient::connect(&s.addr().to_string()).unwrap();
        // Two sheds + one success = exactly three calls.
        assert_eq!(client.call_retry(&Request::Ping, &policy).unwrap(), Response::Pong);
        assert_eq!(calls.load(Ordering::SeqCst), 3);
        // Non-retryable kinds return immediately (one call, no sleeps).
        let before = calls.load(Ordering::SeqCst);
        assert!(client.call_retry(&Request::Status, &policy).is_err());
        assert_eq!(calls.load(Ordering::SeqCst), before + 1);
    }

    #[test]
    fn call_retry_gives_up_after_budget() {
        use crate::base::error::ErrorKind;
        let s = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(|_| Response::Error {
                kind: ErrorKind::Unavailable,
                message: "always overloaded".into(),
            }),
        )
        .unwrap();
        let mut client = RpcClient::connect(&s.addr().to_string()).unwrap();
        let policy = RetryPolicy {
            max_attempts: 2,
            initial_backoff: Duration::from_millis(1),
            ..Default::default()
        };
        let err = client.call_retry(&Request::Ping, &policy).unwrap_err();
        assert!(err.to_string().contains("giving up after 2"), "{err}");
        assert_eq!(ErrorKind::of(&err), ErrorKind::Unavailable, "{err}");
    }

    #[test]
    fn connect_to_dead_addr_fails() {
        assert!(RpcClient::connect("127.0.0.1:1").is_err());
        assert!(RpcClient::connect_timeout(
            "127.0.0.1:1",
            Duration::from_millis(100)
        )
        .is_err());
    }
}
