//! Synchronous RPC client + a small connection pool.

use super::frame::{read_frame_into, write_framed};
use super::proto::{Request, Response};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

/// One connection; one request in flight at a time. Encode/decode
/// scratch buffers persist across calls, so a pooled connection issues
/// steady-state requests without per-call allocations.
pub struct RpcClient {
    stream: TcpStream,
    addr: String,
    encode_buf: Vec<u8>,
    payload_buf: Vec<u8>,
}

impl RpcClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            addr: addr.to_string(),
            encode_buf: Vec::new(),
            payload_buf: Vec::new(),
        })
    }

    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Self> {
        let sock_addr: std::net::SocketAddr =
            addr.parse().with_context(|| format!("parse addr {addr}"))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true)?;
        Ok(RpcClient {
            stream,
            addr: addr.to_string(),
            encode_buf: Vec::new(),
            payload_buf: Vec::new(),
        })
    }

    /// Issue one request and wait for the response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        // Header reserved in the scratch buffer: one write syscall.
        req.encode_framed_into(&mut self.encode_buf);
        write_framed(&mut self.stream, &mut self.encode_buf)?;
        if !read_frame_into(&mut self.stream, &mut self.payload_buf)? {
            return Err(anyhow!("{}: connection closed mid-call", self.addr));
        }
        Response::decode(&self.payload_buf)
    }

    /// `call` + error-response unwrapping.
    pub fn call_ok(&mut self, req: &Request) -> Result<Response> {
        self.call(req)?.into_result()
    }

    /// Set a read deadline for subsequent calls (hedging uses this).
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout)?;
        Ok(())
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }
}

/// Pool of reusable connections per address.
#[derive(Default)]
pub struct ClientPool {
    idle: Mutex<HashMap<String, Vec<RpcClient>>>,
}

impl ClientPool {
    pub fn new() -> Self {
        ClientPool::default()
    }

    /// Check out a connection (reusing an idle one if available).
    pub fn get(&self, addr: &str) -> Result<RpcClient> {
        if let Some(c) = self
            .idle
            .lock()
            .unwrap()
            .get_mut(addr)
            .and_then(|v| v.pop())
        {
            return Ok(c);
        }
        RpcClient::connect(addr)
    }

    /// Return a healthy connection for reuse.
    pub fn put(&self, client: RpcClient) {
        let mut idle = self.idle.lock().unwrap();
        let v = idle.entry(client.addr.clone()).or_default();
        if v.len() < 16 {
            v.push(client);
        }
    }

    /// One-shot convenience: get → call → put (skip put on error).
    pub fn call(&self, addr: &str, req: &Request) -> Result<Response> {
        let mut client = self.get(addr)?;
        match client.call(req) {
            Ok(resp) => {
                self.put(client);
                Ok(resp)
            }
            Err(e) => Err(e), // drop broken connection
        }
    }

    pub fn idle_count(&self, addr: &str) -> usize {
        self.idle.lock().unwrap().get(addr).map_or(0, |v| v.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use std::sync::Arc;

    fn server() -> Arc<RpcServer> {
        RpcServer::start(
            "127.0.0.1:0",
            Arc::new(|req| match req {
                Request::Ping => Response::Pong,
                _ => Response::Error {
                    kind: crate::base::error::ErrorKind::Internal,
                    message: "no".into(),
                },
            }),
        )
        .unwrap()
    }

    #[test]
    fn call_ok_unwraps_errors() {
        let s = server();
        let mut c = RpcClient::connect(&s.addr().to_string()).unwrap();
        assert!(c.call_ok(&Request::Ping).is_ok());
        assert!(c.call_ok(&Request::Status).is_err());
    }

    #[test]
    fn pool_reuses_connections() {
        let s = server();
        let addr = s.addr().to_string();
        let pool = ClientPool::new();
        assert_eq!(pool.idle_count(&addr), 0);
        pool.call(&addr, &Request::Ping).unwrap();
        assert_eq!(pool.idle_count(&addr), 1);
        pool.call(&addr, &Request::Ping).unwrap();
        assert_eq!(pool.idle_count(&addr), 1); // reused, not grown
    }

    #[test]
    fn connect_to_dead_addr_fails() {
        assert!(RpcClient::connect("127.0.0.1:1").is_err());
        assert!(RpcClient::connect_timeout(
            "127.0.0.1:1",
            Duration::from_millis(100)
        )
        .is_err());
    }
}
