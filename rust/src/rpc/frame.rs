//! Length-prefixed framing over a byte stream.
//!
//! `[u32 le length][payload]`, with a hard cap to stop a corrupt or
//! malicious peer from making us allocate gigabytes.

use anyhow::{bail, Result};
use std::io::{Read, Write};

/// Maximum frame payload (64 MiB — far above any batch we serve).
pub const MAX_FRAME: usize = 64 << 20;

/// Frame header size: a u32 little-endian payload length.
pub const HEADER: usize = 4;

/// Write one frame (two `write_all` calls: header, then payload).
/// Connection loops prefer [`write_framed`], which issues one syscall
/// by reserving the header inside the encode buffer.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!("frame too large: {} bytes", payload.len());
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Write one frame whose buffer was built with [`HEADER`] reserved
/// bytes at the front (see `Request::encode_framed_into` /
/// `Response::encode_framed_into`): the length header is patched in
/// place and the whole frame goes out in a **single** `write_all` —
/// one syscall on the reply path instead of two.
pub fn write_framed<W: Write>(w: &mut W, frame: &mut [u8]) -> Result<()> {
    let payload = frame
        .len()
        .checked_sub(HEADER)
        .ok_or_else(|| anyhow::anyhow!("frame buffer smaller than its {HEADER}-byte header"))?;
    if payload > MAX_FRAME {
        bail!("frame too large: {payload} bytes");
    }
    frame[..HEADER].copy_from_slice(&(payload as u32).to_le_bytes());
    w.write_all(frame)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Returns `None` on clean EOF at a frame boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut payload = Vec::new();
    Ok(read_frame_into(r, &mut payload)?.then_some(payload))
}

/// Read one frame into a caller-owned buffer, reusing its capacity
/// (the connection-loop variant: one allocation per connection, not
/// per request). Returns `false` on clean EOF at a frame boundary;
/// `true` means `buf` holds exactly one frame's payload.
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut Vec<u8>) -> Result<bool> {
    let mut len_buf = [0u8; 4];
    // Clean EOF only if zero bytes of the header arrive.
    match r.read(&mut len_buf) {
        Ok(0) => return Ok(false),
        Ok(n) if n < 4 => r.read_exact(&mut len_buf[n..])?,
        Ok(_) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(false),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        bail!("incoming frame too large: {len} bytes");
    }
    buf.clear();
    buf.reserve(len);
    // read_to_end appends into spare capacity without the full-payload
    // zero-fill a resize + read_exact would pay.
    let got = r.by_ref().take(len as u64).read_to_end(buf)?;
    if got < len {
        bail!("truncated frame: got {got} of {len} payload bytes");
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), vec![7u8; 1000]);
        assert!(read_frame(&mut cur).unwrap().is_none()); // clean EOF
    }

    #[test]
    fn read_into_reuses_capacity() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &[9u8; 4096]).unwrap();
        write_frame(&mut buf, b"tiny").unwrap();
        write_frame(&mut buf, &[1u8; 100]).unwrap();
        let mut cur = Cursor::new(buf);
        let mut payload = Vec::new();
        assert!(read_frame_into(&mut cur, &mut payload).unwrap());
        assert_eq!(payload, vec![9u8; 4096]);
        let cap = payload.capacity();
        assert!(read_frame_into(&mut cur, &mut payload).unwrap());
        assert_eq!(payload, b"tiny");
        assert!(read_frame_into(&mut cur, &mut payload).unwrap());
        assert_eq!(payload, vec![1u8; 100]);
        assert_eq!(payload.capacity(), cap, "buffer was reallocated");
        assert!(!read_frame_into(&mut cur, &mut payload).unwrap()); // clean EOF
    }

    #[test]
    fn write_framed_single_buffer_roundtrip() {
        // [4 reserved bytes][payload] → one write, readable by read_frame.
        let mut frame = vec![0u8; HEADER];
        frame.extend_from_slice(b"payload");
        let mut wire = Vec::new();
        write_framed(&mut wire, &mut frame).unwrap();
        assert_eq!(wire.len(), HEADER + 7);
        let mut cur = Cursor::new(wire);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"payload");
        assert!(read_frame(&mut cur).unwrap().is_none());
        // Empty payload is legal; a buffer without room for the header
        // is not.
        let mut empty = vec![0u8; HEADER];
        write_framed(&mut Vec::new(), &mut empty).unwrap();
        let mut too_small = vec![0u8; HEADER - 1];
        assert!(write_framed(&mut Vec::new(), &mut too_small).is_err());
    }

    #[test]
    fn truncated_frame_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut cur = Cursor::new(buf);
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = Cursor::new(buf);
        assert!(read_frame(&mut cur).is_err());
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(write_frame(&mut Vec::new(), &big).is_err());
    }

    #[test]
    fn over_tcp_socket() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let got = read_frame(&mut s).unwrap().unwrap();
            write_frame(&mut s, &got).unwrap(); // echo
        });
        let mut c = std::net::TcpStream::connect(addr).unwrap();
        write_frame(&mut c, b"ping").unwrap();
        assert_eq!(read_frame(&mut c).unwrap().unwrap(), b"ping");
        t.join().unwrap();
    }
}
