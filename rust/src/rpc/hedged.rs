//! Hedged backup requests (§3.1, after Dean's "tail at scale"):
//! "The Router uses hedged backup requests to mitigate latency spikes
//! from transient server issues or inter-request or -model
//! interference."
//!
//! Strategy: send to a primary replica; if no response arrives within
//! `hedge_delay` (ideally ≈ p95 of healthy latency), send the same
//! request to a backup replica; first response wins. Costs a bounded
//! fraction of duplicate work, removes most of the tail. Experiment T6
//! (`benches/bench_hedging.rs`) reproduces the claim.

use super::client::ClientPool;
use super::proto::{Request, Response};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

pub struct HedgedClient {
    pool: Arc<ClientPool>,
    /// Wait this long before firing the backup request.
    pub hedge_delay: Duration,
    hedges_fired: AtomicU64,
    calls: AtomicU64,
}

impl HedgedClient {
    pub fn new(pool: Arc<ClientPool>, hedge_delay: Duration) -> Self {
        HedgedClient {
            pool,
            hedge_delay,
            hedges_fired: AtomicU64::new(0),
            calls: AtomicU64::new(0),
        }
    }

    /// Call `replicas[0]`, hedging to `replicas[1..]` after the delay.
    /// First successful response wins; losers are discarded (their
    /// connections are dropped, not pooled, to avoid response skew).
    pub fn call(&self, replicas: &[String], req: &Request) -> Result<Response> {
        self.call_observed(replicas, req, &mut |_, _| {})
    }

    /// [`HedgedClient::call`] with a per-attempt outcome observer:
    /// `observe(addr, result)` fires once for every attempt that
    /// *completed* (never for an attempt still in flight when a rival
    /// won) — the Router's circuit breakers feed on this.
    ///
    /// Attempt policy: the request walks the replica list in order and
    /// **never re-sends to a replica that already failed it** — a
    /// failure immediately fails over to the next *untried* replica
    /// (so a dead replica costs one attempt, not the whole hedge
    /// budget), while a *slow* primary hedges to the next untried
    /// replica once after `hedge_delay`.
    pub fn call_observed(
        &self,
        replicas: &[String],
        req: &Request,
        observe: &mut dyn FnMut(&str, &Result<Response>),
    ) -> Result<Response> {
        const ATTEMPT_TIMEOUT: Duration = Duration::from_secs(30);
        self.calls.fetch_add(1, Ordering::Relaxed);
        if replicas.is_empty() {
            return Err(anyhow!("no replicas to call"));
        }

        let (tx, rx) = mpsc::channel::<(usize, Result<Response>)>();
        let mut next = 0usize; // next untried replica
        let mut outstanding = 0usize;
        let mut timeout_hedged = false; // at most one latency hedge

        self.spawn_attempt(next, replicas[next].clone(), req.clone(), tx.clone());
        next += 1;
        outstanding += 1;

        let mut last_err: Option<anyhow::Error> = None;
        loop {
            // A latency hedge is worth waiting for only while an
            // untried replica exists and we haven't already fired one.
            let can_hedge = !timeout_hedged && next < replicas.len();
            let wait = if can_hedge { self.hedge_delay } else { ATTEMPT_TIMEOUT };
            match rx.recv_timeout(wait) {
                Ok((idx, Ok(resp))) => {
                    let won = Ok(resp);
                    observe(&replicas[idx], &won);
                    return won;
                }
                Ok((idx, Err(e))) => {
                    // Observe the original error so classification by
                    // ErrorKind still works downstream.
                    let failed: Result<Response> = Err(e);
                    observe(&replicas[idx], &failed);
                    outstanding -= 1;
                    last_err = failed.err();
                    // Fast failover: skip the failed replica for the
                    // rest of this request, try the next untried one.
                    if next < replicas.len() {
                        self.hedges_fired.fetch_add(1, Ordering::Relaxed);
                        self.spawn_attempt(next, replicas[next].clone(), req.clone(), tx.clone());
                        next += 1;
                        outstanding += 1;
                    } else if outstanding == 0 {
                        return Err(last_err.unwrap());
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    if can_hedge {
                        // Slow primary: hedge once to a fresh replica;
                        // first response (either attempt) wins.
                        timeout_hedged = true;
                        self.hedges_fired.fetch_add(1, Ordering::Relaxed);
                        self.spawn_attempt(next, replicas[next].clone(), req.clone(), tx.clone());
                        next += 1;
                        outstanding += 1;
                    } else {
                        return Err(last_err
                            .unwrap_or_else(|| anyhow!("all hedged attempts timed out")));
                    }
                }
                Err(e) => return Err(anyhow!("hedge channel: {e}")),
            }
        }
    }

    fn spawn_attempt(
        &self,
        idx: usize,
        addr: String,
        req: Request,
        tx: mpsc::Sender<(usize, Result<Response>)>,
    ) {
        let pool = Arc::clone(&self.pool);
        std::thread::Builder::new()
            .name("hedge-attempt".to_string())
            .spawn(move || {
                let result = pool
                    .get(&addr)
                    .and_then(|mut c| {
                        let r = c.call(&req);
                        if r.is_ok() {
                            pool.put(c);
                        }
                        r
                    })
                    .and_then(Response::into_result);
                let _ = tx.send((idx, result));
            })
            .expect("spawn hedge attempt");
    }

    /// Fraction of calls that fired a backup request.
    pub fn hedge_rate(&self) -> f64 {
        let calls = self.calls.load(Ordering::Relaxed);
        if calls == 0 {
            0.0
        } else {
            self.hedges_fired.load(Ordering::Relaxed) as f64 / calls as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpc::server::RpcServer;
    use std::sync::atomic::AtomicBool;

    /// Server whose handler can be made artificially slow.
    fn server(slow: Arc<AtomicBool>, delay: Duration) -> Arc<RpcServer> {
        RpcServer::start(
            "127.0.0.1:0",
            Arc::new(move |req| {
                if slow.load(Ordering::SeqCst) {
                    std::thread::sleep(delay);
                }
                match req {
                    Request::Ping => Response::Pong,
                    _ => Response::Error {
                        kind: crate::base::error::ErrorKind::Internal,
                        message: "no".into(),
                    },
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn fast_primary_no_hedge() {
        let s = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(100));
        let replicas = vec![s.addr().to_string()];
        for _ in 0..10 {
            assert_eq!(h.call(&replicas, &Request::Ping).unwrap(), Response::Pong);
        }
        assert_eq!(h.hedge_rate(), 0.0);
    }

    #[test]
    fn slow_primary_hedges_to_backup() {
        let slow = Arc::new(AtomicBool::new(true));
        let primary = server(Arc::clone(&slow), Duration::from_millis(500));
        let backup = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(20));
        let replicas = vec![primary.addr().to_string(), backup.addr().to_string()];

        let t0 = std::time::Instant::now();
        assert_eq!(h.call(&replicas, &Request::Ping).unwrap(), Response::Pong);
        // Must return via the backup (~20ms + rtt), far below 500ms.
        assert!(t0.elapsed() < Duration::from_millis(300), "{:?}", t0.elapsed());
        assert!(h.hedge_rate() > 0.0);
    }

    #[test]
    fn dead_primary_fails_over() {
        let backup = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(50));
        let replicas = vec!["127.0.0.1:1".to_string(), backup.addr().to_string()];
        assert_eq!(h.call(&replicas, &Request::Ping).unwrap(), Response::Pong);
    }

    #[test]
    fn failed_replicas_are_skipped_not_rehedged() {
        // Two dead replicas before a live one: the request must walk
        // the list (one attempt per dead replica, never re-sending to
        // a replica that already failed it) and succeed via the third.
        let live = server(Arc::new(AtomicBool::new(false)), Duration::ZERO);
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(50));
        let replicas = vec![
            "127.0.0.1:1".to_string(),
            "127.0.0.1:1".to_string(),
            live.addr().to_string(),
        ];
        let mut attempts: Vec<(String, bool)> = Vec::new();
        let resp = h
            .call_observed(&replicas, &Request::Ping, &mut |addr, result| {
                attempts.push((addr.to_string(), result.is_ok()));
            })
            .unwrap();
        assert_eq!(resp, Response::Pong);
        // Exactly three attempts: dead, dead, live — no replica tried
        // twice within the request.
        assert_eq!(attempts.len(), 3, "{attempts:?}");
        assert_eq!(attempts[0], ("127.0.0.1:1".to_string(), false));
        assert_eq!(attempts[1], ("127.0.0.1:1".to_string(), false));
        assert_eq!(attempts[2], (live.addr().to_string(), true));
    }

    #[test]
    fn app_errors_reported_to_observer_with_kind() {
        // A server that answers with a typed app error: the observer
        // must see the original ErrorKind, not a flattened transport
        // failure — breakers must not trip on client mistakes.
        let s = RpcServer::start(
            "127.0.0.1:0",
            Arc::new(|_| Response::Error {
                kind: crate::base::error::ErrorKind::InvalidArgument,
                message: "bad shape".into(),
            }),
        )
        .unwrap();
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(50));
        let mut kinds = Vec::new();
        let _ = h.call_observed(
            &[s.addr().to_string()],
            &Request::Ping,
            &mut |_, result| {
                if let Err(e) = result {
                    kinds.push(crate::base::error::ErrorKind::of(e));
                }
            },
        );
        assert_eq!(kinds, vec![crate::base::error::ErrorKind::InvalidArgument]);
    }

    #[test]
    fn no_replicas_errors() {
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(1));
        assert!(h.call(&[], &Request::Ping).is_err());
    }

    #[test]
    fn single_dead_replica_errors() {
        let h = HedgedClient::new(Arc::new(ClientPool::new()), Duration::from_millis(10));
        assert!(h.call(&["127.0.0.1:1".to_string()], &Request::Ping).is_err());
    }
}
